//! Experiment harnesses regenerating every figure/table of the paper's
//! evaluation (§5). Absolute numbers come from our simulator substrate;
//! the claims under reproduction are the *relative* effects (who wins,
//! roughly by how much, where it inverts).

use super::benchmarks::{registry, Benchmark};
use crate::backend::emit::SharedMemMapping;
use crate::driver::{compile_program, CacheStats, Session, VoltError, VoltOptions};
use crate::prof::counters::StallBreakdown;
use crate::prof::report::KernelProfile;
use crate::runtime::{LaunchPolicy, VoltDevice};
use crate::serve::{synthetic, ServeConfig, ServeReport, Service};
use crate::sim::{CacheConfig, FaultPlan, SimConfig, SimStats};
use crate::target::TargetDesc;
use crate::transform::OptLevel;

#[derive(Debug, Clone)]
pub struct RunResult {
    pub stats: SimStats,
    pub compile_ms: f64,
    pub middle_ms: f64,
    pub code_size: usize,
    /// Static regalloc spill-traffic instructions linked into the image
    /// ([`crate::backend::emit::ProgramImage::spill_insts`]).
    pub spill_insts: usize,
}

/// The driver options a benchmark run uses.
fn bench_options(
    b: &Benchmark,
    opt: OptLevel,
    warp_hw: bool,
    smem: SharedMemMapping,
    sim_cfg: SimConfig,
) -> VoltOptions {
    VoltOptions {
        dialect: b.dialect,
        warp_hw,
        opt,
        smem,
        sim: sim_cfg,
        ..VoltOptions::default()
    }
}

pub fn run_bench(
    b: &Benchmark,
    opt: OptLevel,
    warp_hw: bool,
    smem: SharedMemMapping,
    sim_cfg: SimConfig,
) -> Result<RunResult, VoltError> {
    let opts = bench_options(b, opt, warp_hw, smem, sim_cfg);
    let prog = compile_program(b.source, &opts)?;
    let mut dev = VoltDevice::new(prog.image.clone(), opts.device_config());
    (b.run)(&mut dev).map_err(|msg| VoltError::Validation {
        msg: format!("{} @ {:?}: {msg}", b.name, opt),
    })?;
    Ok(RunResult {
        stats: dev.total_stats,
        compile_ms: prog.timings.total_ms(),
        middle_ms: prog.timings.middle_ms,
        code_size: prog.image.code.len(),
        spill_insts: prog.image.spill_insts(),
    })
}

/// Resilience counters from a [`run_bench_resilient`] run.
#[derive(Debug, Clone)]
pub struct ResilienceReport {
    /// Faults the simulator actually injected.
    pub injected: u64,
    /// Launch retries the device performed.
    pub retries: u64,
    /// Launches that trapped at least once but ultimately succeeded.
    pub recovered: u64,
    /// Human-readable log of every injected fault.
    pub fault_log: Vec<String>,
    /// Compile-cache counters (disk fields populated when `cache_dir`
    /// was given).
    pub cache: CacheStats,
    /// Corrupt disk entries quarantined under the cache directory.
    pub quarantined: usize,
}

/// [`run_bench`] under `volt::resilience`: a deterministic [`FaultPlan`]
/// armed on the device, a [`LaunchPolicy`] retrying transient traps, and
/// optionally the persistent compile cache at `cache_dir`. The
/// benchmark's own validator still checks the results, so `Ok` means
/// every injected fault was contained and recovered with correct output.
pub fn run_bench_resilient(
    b: &Benchmark,
    opt: OptLevel,
    faults: FaultPlan,
    policy: LaunchPolicy,
    cache_dir: Option<&std::path::Path>,
) -> Result<(RunResult, ResilienceReport), VoltError> {
    let sim = SimConfig {
        faults,
        ..SimConfig::default()
    };
    let opts = bench_options(b, opt, true, SharedMemMapping::Local, sim);
    let session = match cache_dir {
        Some(dir) => Session::with_disk_cache(opts, dir, 0),
        None => Session::new(opts),
    };
    let prog = session.compile(b.source)?;
    let mut dev = VoltDevice::new(prog.image.clone(), session.options().device_config());
    dev.policy = policy;
    (b.run)(&mut dev).map_err(|msg| VoltError::Validation {
        msg: format!("{} @ {:?}: {msg}", b.name, opt),
    })?;
    let report = ResilienceReport {
        injected: dev.gpu.faults.injected() as u64,
        retries: dev.retries_performed,
        recovered: dev.launches_recovered,
        fault_log: dev.gpu.faults.log.clone(),
        cache: session.cache_stats(),
        quarantined: session.disk_quarantined().unwrap_or(0),
    };
    Ok((
        RunResult {
            stats: dev.total_stats,
            compile_ms: prog.timings.total_ms(),
            middle_ms: prog.timings.middle_ms,
            code_size: prog.image.code.len(),
            spill_insts: prog.image.spill_insts(),
        },
        report,
    ))
}

/// `volt serve --synthetic`: run the seeded synthetic serving workload
/// (`cfg.seed` seeds it) through one [`Service`] batch. The
/// programmatic entry shared by the CLI and the `serve_api`
/// integration test — fixed `(count, cfg)` renders a byte-identical
/// report on every call.
pub fn serve_synthetic(count: usize, cfg: ServeConfig) -> ServeReport {
    let seed = cfg.seed;
    Service::new(cfg).run(synthetic(count, seed))
}

/// [`run_bench`] against an explicit target: device geometry from
/// [`SimConfig::from_target`] and warp builtins lowered to hardware
/// primitives only when the target implements them. No separate
/// gated-op audit is needed here: `build_image` already refuses to link
/// an image containing an op outside the target's feature set.
pub fn run_bench_on(
    b: &Benchmark,
    target: &TargetDesc,
    opt: OptLevel,
) -> Result<RunResult, VoltError> {
    run_bench_on_threads(b, target, opt, 1)
}

/// [`run_bench_on`] with an explicit host worker-thread count for the
/// simulator (and the per-function compile stages): `1` = sequential,
/// `0` = one per available hardware thread. Cycles, results and
/// profiles are bit-identical at any count — threads only change wall
/// clock.
pub fn run_bench_on_threads(
    b: &Benchmark,
    target: &TargetDesc,
    opt: OptLevel,
    threads: usize,
) -> Result<RunResult, VoltError> {
    run_bench_on_configured(b, target, opt, threads, true)
}

/// [`run_bench_on_threads`] with the simulator's trace JIT
/// ([`SimConfig::jit`]) explicitly on or off — the bench matrix axis of
/// `benches/sim_throughput.rs`. Like `threads`, the knob only changes
/// wall clock: stats, results and profiles are bit-identical either
/// way (`rust/tests/jit_api.rs`).
pub fn run_bench_on_configured(
    b: &Benchmark,
    target: &TargetDesc,
    opt: OptLevel,
    threads: usize,
    jit: bool,
) -> Result<RunResult, VoltError> {
    // One derivation of "the profile's defaults": the builder's
    // target_desc() sets geometry and warp lowering from the profile.
    let mut opts = VoltOptions::builder()
        .dialect(b.dialect)
        .target_desc(*target)
        .opt_level(opt)
        .build()?;
    opts.sim.threads = threads;
    opts.sim.jit = jit;
    let prog = compile_program(b.source, &opts)?;
    let mut dev = VoltDevice::new(prog.image.clone(), opts.device_config());
    (b.run)(&mut dev).map_err(|msg| VoltError::Validation {
        msg: format!("{} @ {:?} on {}: {msg}", b.name, opt, target.name),
    })?;
    Ok(RunResult {
        stats: dev.total_stats,
        compile_ms: prog.timings.total_ms(),
        middle_ms: prog.timings.middle_ms,
        code_size: prog.image.code.len(),
        spill_insts: prog.image.spill_insts(),
    })
}

// ---------------------------------------------------------------------------
// Figures 7 & 8: the optimization ladder
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct LadderRow {
    pub name: &'static str,
    /// Per-ladder-level dynamic warp-instruction counts (Fig. 7 raw).
    pub instrs: Vec<u64>,
    /// Per-ladder-level cycles (Fig. 8 raw).
    pub cycles: Vec<u64>,
    /// Per-level memory requests (the ZiCond density effect).
    pub mem_requests: Vec<u64>,
}

impl LadderRow {
    /// Fig. 7 metric: instruction-reduction factor vs Base (higher = better).
    pub fn reduction(&self, level: usize) -> f64 {
        self.instrs[0] as f64 / self.instrs[level] as f64
    }
    /// Fig. 8 metric: speedup vs Base.
    pub fn speedup(&self, level: usize) -> f64 {
        self.cycles[0] as f64 / self.cycles[level] as f64
    }
}

/// Run the full ladder over the (non-warp-feature) suite.
pub fn ladder_sweep(names: Option<&[&str]>) -> Result<Vec<LadderRow>, VoltError> {
    let mut rows = vec![];
    for b in registry() {
        if b.warp_feature {
            continue;
        }
        if let Some(ns) = names {
            if !ns.contains(&b.name) {
                continue;
            }
        }
        let mut row = LadderRow {
            name: b.name,
            instrs: vec![],
            cycles: vec![],
            mem_requests: vec![],
        };
        for lvl in OptLevel::LADDER {
            let r = run_bench(
                &b,
                lvl,
                true,
                SharedMemMapping::Local,
                SimConfig::default(),
            )?;
            row.instrs.push(r.stats.instrs);
            row.cycles.push(r.stats.cycles);
            row.mem_requests.push(r.stats.mem_requests);
        }
        rows.push(row);
    }
    Ok(rows)
}

// ---------------------------------------------------------------------------
// The O3 rung: Recon vs O3 simulated cycles over the full 28-kernel corpus
// (the repo's perf-trajectory baseline, serialized to BENCH_cycles.json by
// benches/o3_cycles.rs)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct O3Row {
    pub name: &'static str,
    pub suite: &'static str,
    pub recon_cycles: u64,
    pub o3_cycles: u64,
    pub recon_instrs: u64,
    pub o3_instrs: u64,
    /// Static spill-traffic instructions in each image (the backend
    /// rung's regalloc upgrade should push the O3 column down).
    pub recon_spills: usize,
    pub o3_spills: usize,
}

impl O3Row {
    /// Cycle-reduction factor vs Recon (>1 means O3 is faster).
    pub fn cycle_reduction(&self) -> f64 {
        self.recon_cycles as f64 / self.o3_cycles as f64
    }
    /// Dynamic-instruction-reduction factor vs Recon.
    pub fn instr_reduction(&self) -> f64 {
        self.recon_instrs as f64 / self.o3_instrs as f64
    }
    pub fn regressed(&self) -> bool {
        self.o3_cycles > self.recon_cycles
    }
}

/// Every kernel in the registry (warp-feature and shared-memory suites
/// included), compiled and *validated* at Recon and at O3; any validator
/// failure propagates as an error.
pub fn o3_cycle_sweep() -> Result<Vec<O3Row>, VoltError> {
    o3_cycle_sweep_on(&TargetDesc::vortex())
}

/// [`o3_cycle_sweep`] against an explicit built-in target (the CI matrix
/// axis): device geometry and warp lowering follow the profile.
pub fn o3_cycle_sweep_on(target: &TargetDesc) -> Result<Vec<O3Row>, VoltError> {
    let mut rows = vec![];
    for b in registry() {
        let recon = run_bench_on(&b, target, OptLevel::Recon)?;
        let o3 = run_bench_on(&b, target, OptLevel::O3)?;
        rows.push(O3Row {
            name: b.name,
            suite: b.suite,
            recon_cycles: recon.stats.cycles,
            o3_cycles: o3.stats.cycles,
            recon_instrs: recon.stats.instrs,
            o3_instrs: o3.stats.instrs,
            recon_spills: recon.spill_insts,
            o3_spills: o3.spill_insts,
        });
    }
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Cross-target differential sweep: every benchmark, every built-in
// target — the §5.3 extensibility acceptance ("compiled correctly for
// each variant from one middle-end")
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct CrossTargetRow {
    pub name: &'static str,
    pub suite: &'static str,
    /// One cell per target: (target name, cycles, instrs, code size).
    pub cells: Vec<(&'static str, u64, u64, usize)>,
}

/// Compile, run and *validate* every registry benchmark on every listed
/// target. Each run re-checks the host-side validator (so outputs are
/// correct on every target independently), and `build_image`'s link-time
/// audit guarantees no feature-gated opcode the target lacks shipped
/// (so e.g. a `vortex-min` image provably contains no
/// `vx_cmov`/`vx_shfl`/`vx_vote`). Any failure anywhere is an error —
/// the sweep passing means all 28 kernels are bit-exact on every
/// target.
pub fn cross_target_sweep(
    targets: &[TargetDesc],
    opt: OptLevel,
) -> Result<Vec<CrossTargetRow>, VoltError> {
    let mut rows = vec![];
    for b in registry() {
        let mut row = CrossTargetRow {
            name: b.name,
            suite: b.suite,
            cells: vec![],
        };
        for t in targets {
            let r = run_bench_on(&b, t, opt)?;
            row.cells
                .push((t.name, r.stats.cycles, r.stats.instrs, r.code_size));
        }
        rows.push(row);
    }
    Ok(rows)
}

// ---------------------------------------------------------------------------
// volt::prof — per-kernel profiles and the suite-wide BENCH_profile.json
// ---------------------------------------------------------------------------

/// Run one benchmark with the profiler attached; returns the usual
/// [`RunResult`] plus one [`KernelProfile`] per launch the validator
/// performed.
pub fn profile_bench(
    b: &Benchmark,
    opt: OptLevel,
) -> Result<(RunResult, Vec<KernelProfile>), VoltError> {
    let sim_cfg = SimConfig::default();
    let opts = bench_options(b, opt, true, SharedMemMapping::Local, sim_cfg);
    let prog = compile_program(b.source, &opts)?;
    let mut dev = VoltDevice::new(prog.image.clone(), opts.device_config());
    dev.profiling = true;
    (b.run)(&mut dev).map_err(|msg| VoltError::Validation {
        msg: format!("{} @ {:?}: {msg}", b.name, opt),
    })?;
    let profiles = dev.take_profiles();
    Ok((
        RunResult {
            stats: dev.total_stats,
            compile_ms: prog.timings.total_ms(),
            middle_ms: prog.timings.middle_ms,
            code_size: prog.image.code.len(),
            spill_insts: prog.image.spill_insts(),
        },
        profiles,
    ))
}

/// One row of the profile sweep (aggregated over a benchmark's launches).
#[derive(Debug, Clone)]
pub struct ProfileRow {
    pub name: &'static str,
    pub suite: &'static str,
    pub launches: usize,
    pub cycles: u64,
    pub instrs: u64,
    pub ipc: f64,
    /// Cycle-weighted average occupancy over launches.
    pub occupancy_pct: f64,
    pub stalls: StallBreakdown,
    /// Executed-PC source-line coverage (distinct PCs, crt0 excluded).
    pub mapped_pct: f64,
    pub l1_hit_rate: f64,
    pub l2_hit_rate: f64,
    /// Hottest source line across all launches: (line, cycles).
    pub hot_line: Option<(u32, u64)>,
    /// Latency-weighted cycles in regalloc spill traffic (all launches).
    pub spill_cycles: u64,
}

/// Profile every kernel in the registry at `opt` (validators run under
/// the profiler) — the raw material of `BENCH_profile.json`.
pub fn profile_sweep(opt: OptLevel) -> Result<Vec<ProfileRow>, VoltError> {
    let mut rows = vec![];
    for b in registry() {
        let (r, profiles) = profile_bench(&b, opt)?;
        let mut stalls = StallBreakdown::default();
        let mut occ_weighted = 0.0f64;
        let mut mapped = 0u64;
        let mut executed = 0u64;
        let mut spill_cycles = 0u64;
        let mut lines: std::collections::HashMap<u32, u64> = Default::default();
        for p in &profiles {
            stalls.add(&p.stalls);
            occ_weighted += p.occupancy_pct * p.cycles as f64;
            mapped += p.pc_mapped;
            executed += p.pc_executed;
            spill_cycles += p.spill_cycles;
            for (l, c) in &p.hot_lines {
                *lines.entry(*l).or_insert(0) += c;
            }
        }
        let mut hot: Vec<(u32, u64)> = lines.into_iter().collect();
        hot.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let s = &r.stats;
        rows.push(ProfileRow {
            name: b.name,
            suite: b.suite,
            launches: profiles.len(),
            cycles: s.cycles,
            instrs: s.instrs,
            ipc: s.ipc(),
            occupancy_pct: if s.cycles > 0 {
                occ_weighted / s.cycles as f64
            } else {
                0.0
            },
            stalls,
            mapped_pct: if executed > 0 {
                mapped as f64 / executed as f64 * 100.0
            } else {
                100.0
            },
            l1_hit_rate: pct(s.l1_hits, s.l1_hits + s.l1_misses),
            l2_hit_rate: pct(s.l2_hits, s.l2_hits + s.l2_misses),
            hot_line: hot.first().copied(),
            spill_cycles,
        });
    }
    Ok(rows)
}

fn pct(num: u64, denom: u64) -> f64 {
    if denom == 0 {
        0.0
    } else {
        num as f64 / denom as f64 * 100.0
    }
}

// ---------------------------------------------------------------------------
// Figure 9: ISA extensions (HW warp primitives vs software emulation)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct IsaExtRow {
    pub name: &'static str,
    pub sw_cycles: u64,
    pub hw_cycles: u64,
    pub sw_instrs: u64,
    pub hw_instrs: u64,
}

impl IsaExtRow {
    pub fn speedup(&self) -> f64 {
        self.sw_cycles as f64 / self.hw_cycles as f64
    }
}

pub fn isa_extension_sweep() -> Result<Vec<IsaExtRow>, VoltError> {
    let mut rows = vec![];
    for b in registry() {
        if !b.warp_feature {
            continue;
        }
        let sw = run_bench(
            &b,
            OptLevel::Recon,
            false,
            SharedMemMapping::Local,
            SimConfig::default(),
        )?;
        let hw = run_bench(
            &b,
            OptLevel::Recon,
            true,
            SharedMemMapping::Local,
            SimConfig::default(),
        )?;
        rows.push(IsaExtRow {
            name: b.name,
            sw_cycles: sw.stats.cycles,
            hw_cycles: hw.stats.cycles,
            sw_instrs: sw.stats.instrs,
            hw_instrs: hw.stats.instrs,
        });
    }
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Figure 10: shared-memory mapping × cache configuration
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct MemCfgRow {
    pub name: &'static str,
    /// (config label, cycles)
    pub cells: Vec<(String, u64)>,
}

pub fn memory_config_sweep() -> Result<Vec<MemCfgRow>, VoltError> {
    let mut rows = vec![];
    let configs: Vec<(String, SharedMemMapping, SimConfig)> = vec![
        (
            "smem=local,L2=on".into(),
            SharedMemMapping::Local,
            SimConfig::default(),
        ),
        (
            "smem=local,L2=off".into(),
            SharedMemMapping::Local,
            SimConfig {
                l2: None,
                ..Default::default()
            },
        ),
        (
            "smem=global,L2=on".into(),
            SharedMemMapping::Global,
            SimConfig::default(),
        ),
        (
            "smem=global,L2=off".into(),
            SharedMemMapping::Global,
            SimConfig {
                l2: None,
                ..Default::default()
            },
        ),
        (
            "smem=local,smallL1".into(),
            SharedMemMapping::Local,
            SimConfig {
                l1d: CacheConfig {
                    sets: 16,
                    ways: 2,
                    line: 64,
                    latency: 2,
                },
                ..Default::default()
            },
        ),
        (
            "smem=global,smallL1".into(),
            SharedMemMapping::Global,
            SimConfig {
                l1d: CacheConfig {
                    sets: 16,
                    ways: 2,
                    line: 64,
                    latency: 2,
                },
                ..Default::default()
            },
        ),
    ];
    for b in registry() {
        if !b.smem {
            continue;
        }
        let mut row = MemCfgRow {
            name: b.name,
            cells: vec![],
        };
        for (label, smem, cfg) in &configs {
            let r = run_bench(&b, OptLevel::Recon, true, *smem, *cfg)?;
            row.cells.push((label.clone(), r.stats.cycles));
        }
        rows.push(row);
    }
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Compile-time overhead (§5.2: "0.18% compile-time geomean slowdown")
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct CompileTimeRow {
    pub name: &'static str,
    pub base_ms: f64,
    pub full_ms: f64,
}

impl CompileTimeRow {
    pub fn overhead_pct(&self) -> f64 {
        (self.full_ms / self.base_ms - 1.0) * 100.0
    }
}

pub fn compile_time_sweep(repeats: u32) -> Result<Vec<CompileTimeRow>, VoltError> {
    let mut rows = vec![];
    for b in registry() {
        let base_opts = VoltOptions {
            dialect: b.dialect,
            opt: OptLevel::Base,
            ..VoltOptions::default()
        };
        let full_opts = VoltOptions {
            opt: OptLevel::Recon,
            ..base_opts
        };
        let mut base = f64::MAX;
        let mut full = f64::MAX;
        for _ in 0..repeats {
            base = base.min(compile_program(b.source, &base_opts)?.timings.total_ms());
            full = full.min(compile_program(b.source, &full_opts)?.timings.total_ms());
        }
        rows.push(CompileTimeRow {
            name: b.name,
            base_ms: base,
            full_ms: full,
        });
    }
    Ok(rows)
}

pub fn geomean(vals: impl Iterator<Item = f64>) -> f64 {
    let mut log_sum = 0.0;
    let mut n = 0usize;
    for v in vals {
        log_sum += v.max(1e-12).ln();
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        (log_sum / n as f64).exp()
    }
}

// ---------------------------------------------------------------------------
// §5.1 coverage: validate the whole suite at every ladder level
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct ValidationRow {
    pub name: &'static str,
    pub suite: &'static str,
    pub results: Vec<(OptLevel, Result<(), String>)>,
}

pub fn validate_all(levels: &[OptLevel]) -> Vec<ValidationRow> {
    let mut rows = vec![];
    for b in registry() {
        let mut results = vec![];
        for &lvl in levels {
            let r = run_bench(
                &b,
                lvl,
                true,
                SharedMemMapping::Local,
                SimConfig::default(),
            )
            .map(|_| ())
            .map_err(|e| e.to_string());
            results.push((lvl, r));
        }
        rows.push(ValidationRow {
            name: b.name,
            suite: b.suite,
            results,
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_sane() {
        let g = geomean([1.0, 4.0].into_iter());
        assert!((g - 2.0).abs() < 1e-9);
    }

    /// A couple of representative benchmarks validate at the ladder ends.
    #[test]
    fn spot_validation() {
        for name in ["saxpy", "reduce", "pathfinder"] {
            let b = super::super::benchmarks::find(name).unwrap();
            for lvl in [OptLevel::Base, OptLevel::Recon, OptLevel::O3] {
                run_bench(
                    &b,
                    lvl,
                    true,
                    SharedMemMapping::Local,
                    SimConfig::default(),
                )
                .unwrap_or_else(|e| panic!("{e}"));
            }
        }
    }

    /// Representative benchmarks validate on both built-in targets; the
    /// warp suite exercises the software-emulation path on vortex-min.
    #[test]
    fn cross_target_spot_validation() {
        for name in ["saxpy", "reduce", "vote"] {
            let b = super::super::benchmarks::find(name).unwrap();
            for t in TargetDesc::builtins() {
                run_bench_on(&b, &t, OptLevel::Recon)
                    .unwrap_or_else(|e| panic!("{name} on {}: {e}", t.name));
            }
        }
    }

    /// The warp suite runs under both lowering modes; HW should not be
    /// slower than SW.
    #[test]
    fn warp_hw_beats_sw() {
        let b = super::super::benchmarks::find("bscan").unwrap();
        let sw = run_bench(&b, OptLevel::Recon, false, SharedMemMapping::Local, SimConfig::default()).unwrap();
        let hw = run_bench(&b, OptLevel::Recon, true, SharedMemMapping::Local, SimConfig::default()).unwrap();
        assert!(
            hw.stats.cycles < sw.stats.cycles,
            "hw {} !< sw {}",
            hw.stats.cycles,
            sw.stats.cycles
        );
    }
}
