//! End-to-end compile pipeline: source → front-end (+ dispatchers) →
//! middle-end ladder → back-end image, with per-stage timing for the
//! compile-time-overhead experiment (§5.2).

use crate::backend::emit::{BackendOptions, ProgramImage};
use crate::frontend::{compile_kernels, FrontendOptions, KernelInfo};
use crate::transform::{run_middle_end, MiddleEndReport, OptLevel};
use std::time::Instant;

#[derive(Debug)]
pub struct CompileOutput {
    pub image: ProgramImage,
    pub middle: MiddleEndReport,
    pub kernels: Vec<KernelInfo>,
    pub frontend_ms: f64,
    pub middle_ms: f64,
    pub backend_ms: f64,
}

impl CompileOutput {
    pub fn total_ms(&self) -> f64 {
        self.frontend_ms + self.middle_ms + self.backend_ms
    }
}

pub fn compile_source(
    src: &str,
    fe: &FrontendOptions,
    opt: OptLevel,
    be: &BackendOptions,
) -> Result<CompileOutput, String> {
    let t0 = Instant::now();
    let (mut m, kernels) = compile_kernels(src, fe).map_err(|e| e.to_string())?;
    let frontend_ms = t0.elapsed().as_secs_f64() * 1e3;
    if kernels.is_empty() {
        return Err("no kernels in source".into());
    }
    let t1 = Instant::now();
    let mut cfg = opt.config();
    cfg.verify = false;
    let middle = run_middle_end(&mut m, &cfg);
    let middle_ms = t1.elapsed().as_secs_f64() * 1e3;
    let t2 = Instant::now();
    let be = BackendOptions {
        zicond: opt >= OptLevel::ZiCond,
        ..*be
    };
    let image = crate::backend::build_image(&m, &format!("__main_{}", kernels[0].name), &be)?;
    let backend_ms = t2.elapsed().as_secs_f64() * 1e3;
    Ok(CompileOutput {
        image,
        middle,
        kernels,
        frontend_ms,
        middle_ms,
        backend_ms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compiles_with_timing() {
        let out = compile_source(
            "kernel void k(global int* o, int n) { int i = get_global_id(0); if (i < n) o[i] = i; }",
            &FrontendOptions::default(),
            OptLevel::Recon,
            &BackendOptions::default(),
        )
        .unwrap();
        assert!(out.total_ms() > 0.0);
        assert_eq!(out.kernels.len(), 1);
        assert!(out.image.code.len() > 20);
    }
}
