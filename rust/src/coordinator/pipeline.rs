//! Deprecated compile entry point, kept as a thin shim over
//! [`crate::driver`] for pre-session callers and tests.
//!
//! New code should use [`crate::driver::Session`]: it adds the binary
//! cache, multi-kernel [`crate::driver::Program`]s and streams. This
//! module only adapts the old `(FrontendOptions, OptLevel,
//! BackendOptions)` triple onto the unified [`VoltOptions`] and flattens
//! the result. Unlike the seed, the produced image carries a launchable
//! entry for *every* kernel in the source, not just `kernels[0]`.

use crate::backend::emit::{BackendOptions, ProgramImage};
use crate::driver::{compile_program, KernelEntry, VoltError, VoltOptions};
use crate::frontend::FrontendOptions;
use crate::sim::SimConfig;
use crate::transform::{MiddleEndReport, OptLevel};

#[derive(Debug)]
pub struct CompileOutput {
    pub image: ProgramImage,
    pub middle: MiddleEndReport,
    /// Launchable entries for every kernel in the source.
    pub kernels: Vec<KernelEntry>,
    pub frontend_ms: f64,
    pub middle_ms: f64,
    pub backend_ms: f64,
}

impl CompileOutput {
    pub fn total_ms(&self) -> f64 {
        self.frontend_ms + self.middle_ms + self.backend_ms
    }
}

/// Deprecated: use [`crate::driver::Session::compile`]. One-shot compile
/// with the legacy split option structs; no caching.
pub fn compile_source(
    src: &str,
    fe: &FrontendOptions,
    opt: OptLevel,
    be: &BackendOptions,
) -> Result<CompileOutput, VoltError> {
    let opts = VoltOptions {
        dialect: fe.dialect,
        warp_hw: fe.warp_hw,
        opt,
        // The old pipeline derived zicond from the ladder level,
        // overriding whatever the caller put in BackendOptions.
        zicond: None,
        opt_layout: be.opt_layout,
        safety_net: be.safety_net,
        smem: be.smem,
        // Forward the caller's target (and its default device geometry,
        // so caps validation checks against the right profile) instead
        // of silently compiling for vortex.
        target: be.target,
        sim: SimConfig::from_target(&be.target),
        ..VoltOptions::default()
    };
    let p = compile_program(src, &opts)?;
    Ok(CompileOutput {
        image: p.image,
        middle: p.middle,
        kernels: p.kernels,
        frontend_ms: p.timings.frontend_ms,
        middle_ms: p.timings.middle_ms,
        backend_ms: p.timings.backend_ms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compiles_with_timing() {
        let out = compile_source(
            "kernel void k(global int* o, int n) { int i = get_global_id(0); if (i < n) o[i] = i; }",
            &FrontendOptions::default(),
            OptLevel::Recon,
            &BackendOptions::default(),
        )
        .unwrap();
        assert!(out.total_ms() > 0.0);
        assert_eq!(out.kernels.len(), 1);
        assert!(out.image.code.len() > 20);
    }

    /// Regression for the seed's `kernels[0]`-only entry: a two-kernel
    /// source must produce launchable entries for both.
    #[test]
    fn multi_kernel_source_links_every_entry() {
        let out = compile_source(
            r#"
kernel void first(global int* o, int n) {
    int i = get_global_id(0);
    if (i < n) o[i] = 1;
}
kernel void second(global int* o, int n) {
    int i = get_global_id(0);
    if (i < n) o[i] = 2;
}
"#,
            &FrontendOptions::default(),
            OptLevel::Recon,
            &BackendOptions::default(),
        )
        .unwrap();
        assert_eq!(out.kernels.len(), 2);
        for k in &out.kernels {
            assert!(
                out.image.func_entries.contains_key(&k.entry_symbol),
                "missing entry for kernel '{}'",
                k.name
            );
        }
        assert_ne!(out.kernels[0].entry_pc, out.kernels[1].entry_pc);
    }
}
