//! `volt::serve` — a batched multi-tenant compile+launch service over
//! simulated devices.
//!
//! The serving front the ROADMAP asks for on top of PR 7's persistent
//! cache and launch-recovery machinery: a [`Service`] accepts a batch
//! of [`ServeRequest`]s (a manifest or the seeded synthetic workload),
//! admits them into a bounded FIFO-with-priority queue, and dispatches
//! them across a pool of N virtual device slots.
//!
//! The three load-bearing properties, in order:
//!
//! * **Shared compile tier.** All requests with the same options
//!   config compile through one [`Session`] (optionally backed by the
//!   on-disk cache), so identical fingerprints within a batch dedup to
//!   a single pipeline run and every outcome records which tier served
//!   it (mem / disk / miss).
//! * **Per-request isolation.** Every request executes on its own
//!   [`Stream`](crate::driver::Stream) over a fresh device. A chaos
//!   request (armed [`FaultPlan`](crate::sim::FaultPlan)) that exhausts
//!   its retry budget latches *its* stream faulted; neighbors never
//!   observe it.
//! * **Determinism.** Scheduling runs in virtual time (earliest-free
//!   device slot; no OS threads, no wall clock anywhere in the ledger),
//!   so a fixed (workload, seed, device count) renders byte-identical
//!   `BENCH_serving.json` on every rerun.
//!
//! See `docs/SERVING.md` for the manifest format, the scheduling and
//! isolation semantics, and the report schema.

pub mod report;
pub mod request;
pub mod scheduler;
pub mod worker;

pub use report::{DeviceUtil, Provenance, RequestOutcome, RequestStatus, ServeReport};
pub use request::{parse_manifest, parse_opt, synthetic, ArgSpec, Payload, Priority, ServeRequest};
pub use scheduler::{DeviceSlot, Scheduler};

use crate::driver::{CacheStats, Session, VoltOptions};
use crate::frontend::Dialect;
use crate::runtime::LaunchPolicy;
use crate::transform::OptLevel;
use std::collections::{BTreeMap, HashMap, HashSet};

/// Service-wide configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Virtual device slots the batch is scheduled across.
    pub devices: usize,
    /// Default launch-retry budget (per-request `retries=` overrides).
    pub retries: u32,
    /// Default retry backoff in simulated cycles.
    pub backoff_cycles: u64,
    /// Admission-queue capacity; 0 = unbounded.
    pub queue_cap: usize,
    /// Persistent compile-cache directory shared by the session pool.
    pub cache_dir: Option<std::path::PathBuf>,
    /// Disk-cache size cap in bytes (0 = uncapped).
    pub cache_max_bytes: u64,
    /// Workload seed, recorded in the report (and used by
    /// [`synthetic`] when the CLI builds the workload).
    pub seed: u32,
    /// Host worker threads draining the admitted batch (1 = the
    /// sequential virtual-time loop, 0 = one per available hardware
    /// thread). The report is schedule-equivalent at any thread count —
    /// see `docs/PARALLELISM.md`.
    pub threads: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            devices: 2,
            retries: 0,
            backoff_cycles: 0,
            queue_cap: 0,
            cache_dir: None,
            cache_max_bytes: 0,
            seed: 1,
            threads: 1,
        }
    }
}

/// The batch service: a session pool keyed by options config plus the
/// virtual-time scheduler.
pub struct Service {
    cfg: ServeConfig,
    /// One shared session per distinct (dialect, ladder level). A
    /// BTreeMap so every iteration (stats aggregation, reporting) walks
    /// sessions in a deterministic order.
    sessions: BTreeMap<(u8, u8), Session>,
}

fn session_key(dialect: Dialect, opt: OptLevel) -> (u8, u8) {
    let d = match dialect {
        Dialect::OpenCL => 0u8,
        Dialect::Cuda => 1u8,
    };
    let o = OptLevel::LADDER
        .iter()
        .position(|l| *l == opt)
        .unwrap_or(OptLevel::LADDER.len()) as u8;
    (d, o)
}

impl Service {
    pub fn new(cfg: ServeConfig) -> Service {
        Service {
            cfg,
            sessions: BTreeMap::new(),
        }
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    fn session_for(&mut self, dialect: Dialect, opt: OptLevel) -> &Session {
        let key = session_key(dialect, opt);
        let cfg = &self.cfg;
        self.sessions.entry(key).or_insert_with(|| {
            let opts = VoltOptions {
                dialect,
                opt,
                ..VoltOptions::default()
            };
            match &cfg.cache_dir {
                Some(dir) => Session::with_disk_cache(opts, dir, cfg.cache_max_bytes),
                None => Session::new(opts),
            }
        })
    }

    /// Compile-cache counters summed over the session pool (plus total
    /// quarantined entries).
    pub fn cache_stats(&self) -> (CacheStats, usize) {
        let mut total = CacheStats::default();
        let mut quarantined = 0;
        for s in self.sessions.values() {
            let c = s.cache_stats();
            total.hits += c.hits;
            total.misses += c.misses;
            total.disk_hits += c.disk_hits;
            total.disk_corrupt += c.disk_corrupt;
            total.disk_evicted += c.disk_evicted;
            quarantined += s.disk_quarantined().unwrap_or(0);
        }
        (total, quarantined)
    }

    /// Run one batch to completion and report. Per-request failures are
    /// *outcomes*, not errors — the service itself cannot fail.
    pub fn run(&mut self, requests: Vec<ServeRequest>) -> ServeReport {
        let dialect_of = |req: &ServeRequest| match &req.payload {
            Payload::Registry { name } => crate::coordinator::benchmarks::find(name)
                .map(|b| b.dialect)
                .unwrap_or(Dialect::OpenCL),
            Payload::Source { dialect, .. } => *dialect,
        };

        let (admitted, rejected) = scheduler::admit(requests, self.cfg.queue_cap);
        // Pre-create the session pool so the execution phase can share
        // it immutably across worker threads.
        for (_, req) in &admitted {
            self.session_for(dialect_of(req), req.opt);
        }

        // Phase A — execution. Admitted requests are independent once
        // the session pool exists: compiles dedup *inside* the shared
        // `Session` (it is `Sync`), and every launch runs on a private
        // device. `threads > 1` fans them out across a worker pool;
        // results come back in admission order regardless.
        let threads = crate::sim::effective_threads(self.cfg.threads);
        let execs: Vec<worker::ExecResult> = {
            let sessions = &self.sessions;
            let cfg = &self.cfg;
            crate::par::par_map(&admitted, threads, |_, (_, req)| {
                let policy = LaunchPolicy {
                    retries: req.retries.unwrap_or(cfg.retries),
                    backoff_cycles: req.backoff.unwrap_or(cfg.backoff_cycles),
                    watchdog_max_cycles: None,
                };
                let session = &sessions[&session_key(dialect_of(req), req.opt)];
                worker::execute(req, session, policy)
            })
        };

        // Phase B — the deterministic virtual-time ledger, replayed in
        // admission order. Under a worker pool, *which* request's thread
        // ran a dedup group's single pipeline is a race; the ledger
        // instead charges it to the group's first-admitted request —
        // exactly what sequential draining produces — so the report is
        // schedule-equivalent: byte-identical at any thread count.
        let group_of = |req: &ServeRequest| {
            let key = session_key(dialect_of(req), req.opt);
            let fp =
                crate::driver::fingerprint(worker::source_of(req), self.sessions[&key].options());
            (key, fp)
        };
        let mut lead_tier: HashMap<((u8, u8), u64), Provenance> = HashMap::new();
        for ((_, req), r) in admitted.iter().zip(&execs) {
            if r.status == RequestStatus::CompileError {
                continue;
            }
            if let Some(p) = r.provenance {
                if p != Provenance::Mem {
                    lead_tier.entry(group_of(req)).or_insert(p);
                }
            }
        }

        let mut sched = Scheduler::new(self.cfg.devices);
        let mut outcomes: Vec<RequestOutcome> = Vec::with_capacity(admitted.len() + rejected.len());
        let mut seen: HashSet<((u8, u8), u64)> = HashSet::new();
        for ((id, req), r) in admitted.iter().zip(&execs) {
            let (provenance, compile_cycles) = if r.status == RequestStatus::CompileError {
                (r.provenance, r.compile_cycles)
            } else {
                let g = group_of(req);
                let p = if seen.insert(g) {
                    // First of its dedup group: charged the pipeline run
                    // (or disk load) the group incurred, if any.
                    lead_tier.get(&g).cloned().unwrap_or(Provenance::Mem)
                } else {
                    Provenance::Mem
                };
                (Some(p), worker::compile_cost(p, r.code_len))
            };
            let (device, start) = sched.assign();
            let service_cycles = compile_cycles + r.launch_cycles;
            sched.complete(device, service_cycles);
            outcomes.push(RequestOutcome {
                id: *id,
                label: req.payload.label().to_string(),
                class: req.class,
                priority: req.priority,
                status: r.status,
                device,
                provenance,
                queue_cycles: start,
                compile_cycles,
                launch_cycles: r.launch_cycles,
                total_cycles: start + service_cycles,
                instrs: r.instrs,
                retries: r.retries,
                recovered: r.recovered,
                injected: r.injected,
                profiles: r.profiles,
                error: r.error.clone(),
            });
        }
        for (id, req) in &rejected {
            outcomes.push(RequestOutcome {
                id: *id,
                label: req.payload.label().to_string(),
                class: req.class,
                priority: req.priority,
                status: RequestStatus::Rejected,
                device: usize::MAX,
                provenance: None,
                queue_cycles: 0,
                compile_cycles: 0,
                launch_cycles: 0,
                total_cycles: 0,
                instrs: 0,
                retries: 0,
                recovered: 0,
                injected: 0,
                profiles: 0,
                error: Some(format!(
                    "rejected at admission: queue capacity {} exceeded",
                    self.cfg.queue_cap
                )),
            });
        }
        // Report in admission order — stable across device counts.
        outcomes.sort_by_key(|o| o.id);

        let makespan = sched.makespan();
        let device_util = sched
            .slots()
            .iter()
            .enumerate()
            .map(|(i, s)| DeviceUtil {
                device: i,
                served: s.served,
                busy_cycles: s.busy_cycles,
                utilization_pct: if makespan == 0 {
                    0.0
                } else {
                    s.busy_cycles as f64 / makespan as f64 * 100.0
                },
            })
            .collect();
        let (cache, quarantined) = self.cache_stats();
        ServeReport {
            devices: self.cfg.devices,
            seed: self.cfg.seed,
            outcomes,
            device_util,
            makespan_cycles: makespan,
            cache,
            quarantined,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The worker-pool contract: everything the thread-per-device
    /// dispatcher moves across threads is `Send`, and everything it
    /// *shares* (the session pool above all) is `Sync`.
    #[test]
    fn service_components_are_send() {
        fn assert_send<T: Send>() {}
        assert_send::<Session>();
        assert_send::<crate::driver::Stream>();
        assert_send::<std::sync::Arc<crate::driver::Program>>();
        assert_send::<Service>();
        assert_send::<ServeRequest>();
        assert_send::<ServeReport>();
    }

    #[test]
    fn service_components_are_sync() {
        fn assert_sync<T: Sync>() {}
        assert_sync::<Session>();
        assert_sync::<std::sync::Arc<crate::driver::Program>>();
        assert_sync::<Service>();
        assert_sync::<ServeRequest>();
    }

    /// Schedule equivalence: the threaded drain must render the *same*
    /// report as the sequential virtual-time loop — outcomes,
    /// provenance, ledger charges, per-device counts, bytes and all.
    #[test]
    fn threaded_run_matches_sequential_report() {
        let batch = || {
            vec![
                ServeRequest::registry("vecadd", OptLevel::Recon),
                ServeRequest::registry("vecadd", OptLevel::Recon),
                ServeRequest::registry("saxpy", OptLevel::Recon),
                ServeRequest::registry("vecadd", OptLevel::O3),
                ServeRequest::registry("saxpy", OptLevel::Recon),
            ]
        };
        let run_with = |threads: usize| {
            let mut svc = Service::new(ServeConfig {
                devices: 2,
                threads,
                ..ServeConfig::default()
            });
            svc.run(batch()).render_json()
        };
        let seq = run_with(1);
        for threads in [2usize, 4] {
            assert_eq!(run_with(threads), seq, "threads={threads}");
        }
    }

    #[test]
    fn session_pool_keys_are_stable() {
        assert_eq!(session_key(Dialect::OpenCL, OptLevel::Base), (0, 0));
        assert_eq!(
            session_key(Dialect::Cuda, OptLevel::O3),
            (1, (OptLevel::LADDER.len() - 1) as u8)
        );
    }

    #[test]
    fn small_batch_end_to_end() {
        let mut svc = Service::new(ServeConfig {
            devices: 2,
            ..ServeConfig::default()
        });
        let reqs = vec![
            ServeRequest::registry("vecadd", OptLevel::Recon),
            ServeRequest::registry("vecadd", OptLevel::Recon),
            ServeRequest::registry("saxpy", OptLevel::Recon),
        ];
        let rep = svc.run(reqs);
        assert_eq!(rep.outcomes.len(), 3);
        assert!(rep.outcomes.iter().all(|o| o.status == RequestStatus::Pass));
        // Dedup-in-flight: two distinct fingerprints, one mem hit.
        assert_eq!(rep.cache.misses, 2);
        assert_eq!(rep.cache.hits, 1);
        assert_eq!(
            rep.outcomes[1].provenance,
            Some(Provenance::Mem),
            "identical request in the same batch must dedup"
        );
        assert!(rep.makespan_cycles > 0);
        let busy: u64 = rep.device_util.iter().map(|d| d.busy_cycles).sum();
        let svc_total: u64 = rep
            .outcomes
            .iter()
            .map(|o| o.compile_cycles + o.launch_cycles)
            .sum();
        assert_eq!(busy, svc_total, "device ledger must balance");
        crate::prof::validate_json(&rep.render_json()).unwrap();
    }
}
