//! Admission and virtual-time device scheduling.
//!
//! The service is deterministic by construction: instead of racing OS
//! threads, the scheduler models the device pool in *virtual time*.
//! Every request arrives at cycle 0; admission orders the queue by
//! (priority, admission sequence) — a stable sort, so FIFO within a
//! class — and dispatch always picks the device slot that frees
//! earliest (lowest index on ties). Queue latency is the virtual cycle
//! at which the request's slot became available; service latency is the
//! deterministic compile-model cost plus the simulated device cycles
//! the request actually consumed. The result is bit-identical
//! scheduling for a fixed (workload, device count) — the property
//! `BENCH_serving.json` diffs in CI.

use super::request::ServeRequest;

/// One simulated device slot's ledger.
#[derive(Clone, Debug, Default)]
pub struct DeviceSlot {
    /// Virtual cycle at which the slot next becomes free.
    pub free_at: u64,
    /// Total cycles of service the slot performed.
    pub busy_cycles: u64,
    /// Requests dispatched to this slot.
    pub served: u32,
}

/// Earliest-free-device dispatcher over `n` virtual slots.
#[derive(Clone, Debug)]
pub struct Scheduler {
    slots: Vec<DeviceSlot>,
}

impl Scheduler {
    pub fn new(devices: usize) -> Scheduler {
        Scheduler {
            slots: vec![DeviceSlot::default(); devices.max(1)],
        }
    }

    /// Pick the slot that frees earliest (lowest index breaks ties) and
    /// return `(device, start_cycle)`. The caller reports the service
    /// time back through [`Scheduler::complete`].
    pub fn assign(&mut self) -> (usize, u64) {
        let device = self
            .slots
            .iter()
            .enumerate()
            .min_by_key(|(i, s)| (s.free_at, *i))
            .map(|(i, _)| i)
            .unwrap();
        (device, self.slots[device].free_at)
    }

    /// Record that `device` spent `service_cycles` on a request
    /// dispatched at its previous `free_at`.
    pub fn complete(&mut self, device: usize, service_cycles: u64) {
        let s = &mut self.slots[device];
        s.free_at += service_cycles;
        s.busy_cycles += service_cycles;
        s.served += 1;
    }

    /// Virtual cycle at which the last slot finishes — the batch
    /// makespan.
    pub fn makespan(&self) -> u64 {
        self.slots.iter().map(|s| s.free_at).max().unwrap_or(0)
    }

    pub fn slots(&self) -> &[DeviceSlot] {
        &self.slots
    }
}

/// Admission: order the batch by (priority, admission seq) — a stable
/// sort, so FIFO within a class — then cap the queue at `capacity`
/// (0 = unbounded). A high-priority request is never turned away while
/// a lower-priority one holds a slot. Returns the admitted requests
/// tagged with their admission ids, in dispatch order, plus the
/// rejected overflow in arrival order.
pub fn admit(
    requests: Vec<ServeRequest>,
    capacity: usize,
) -> (Vec<(usize, ServeRequest)>, Vec<(usize, ServeRequest)>) {
    let mut admitted: Vec<(usize, ServeRequest)> = requests.into_iter().enumerate().collect();
    admitted.sort_by_key(|(seq, r)| (r.priority, *seq));
    let mut rejected = vec![];
    if capacity > 0 && admitted.len() > capacity {
        rejected = admitted.split_off(capacity);
        rejected.sort_by_key(|(seq, _)| *seq);
    }
    (admitted, rejected)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::request::Priority;
    use crate::transform::OptLevel;

    fn req(prio: Priority) -> ServeRequest {
        let mut r = ServeRequest::registry("vecadd", OptLevel::Recon);
        r.priority = prio;
        r
    }

    #[test]
    fn earliest_free_device_lowest_index_ties() {
        let mut s = Scheduler::new(2);
        let (d0, t0) = s.assign();
        assert_eq!((d0, t0), (0, 0), "tie goes to the lowest index");
        s.complete(d0, 100);
        let (d1, t1) = s.assign();
        assert_eq!((d1, t1), (1, 0));
        s.complete(d1, 40);
        // Device 1 frees at 40 < device 0 at 100.
        let (d2, t2) = s.assign();
        assert_eq!((d2, t2), (1, 40));
        s.complete(d2, 100);
        assert_eq!(s.makespan(), 140);
        assert_eq!(s.slots()[0].served, 1);
        assert_eq!(s.slots()[1].served, 2);
    }

    #[test]
    fn admission_is_priority_then_fifo_with_cap() {
        let reqs = vec![
            req(Priority::Normal),
            req(Priority::Low),
            req(Priority::High),
            req(Priority::Normal),
            req(Priority::High),
        ];
        let (adm, rej) = admit(reqs.clone(), 4);
        assert_eq!(rej.len(), 1);
        assert_eq!(rej[0].0, 1, "the lone Low arrival loses its slot");
        let order: Vec<usize> = adm.iter().map(|(s, _)| *s).collect();
        assert_eq!(order, vec![2, 4, 0, 3], "priority first, FIFO within");
        let (adm_all, rej_none) = admit(reqs, 0);
        let order: Vec<usize> = adm_all.iter().map(|(s, _)| *s).collect();
        assert_eq!(order, vec![2, 4, 0, 3, 1]);
        assert!(rej_none.is_empty(), "capacity 0 means unbounded");
    }
}
