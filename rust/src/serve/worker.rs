//! Per-request execution: the compile tier, the isolation boundary and
//! the latency ledger.
//!
//! A request compiles through the service's *shared* [`Session`] pool
//! (in-memory + optional disk tier — identical fingerprints within a
//! batch dedup to one pipeline run) and then executes on its *own*
//! [`Stream`] over a fresh device. That asymmetry is the whole design:
//! compiles are pure and safe to share; execution is where faults live,
//! so a poisoned request latches only its private device/stream (PR 7's
//! sticky-fault semantics) and its neighbors never observe it.
//!
//! Compile latency is charged from a deterministic cost model (wall
//! clock would destroy run-to-run bit-identity): a full compile costs
//! `2000 + 10·code_len` virtual cycles, a disk hit `400 + code_len`
//! (read + checksum + decode), an in-memory hit a flat `50`. Launch
//! latency is the *real* simulated device cycle count, including
//! retry/backoff charges.

use super::report::{Provenance, RequestStatus};
use super::request::{ArgSpec, Payload, ServeRequest};
use crate::coordinator::benchmarks;
use crate::driver::{CompileTier, Session, Stream};
use crate::runtime::{ArgValue, LaunchPolicy};
use crate::sim::FaultState;

/// Virtual-cycle compile-cost model (documented in `docs/SERVING.md`).
pub fn compile_cost(provenance: Provenance, code_len: usize) -> u64 {
    match provenance {
        Provenance::Miss => 2_000 + 10 * code_len as u64,
        Provenance::Disk => 400 + code_len as u64,
        Provenance::Mem => 50,
    }
}

/// What [`execute`] hands back to the scheduler loop.
#[derive(Clone, Debug)]
pub struct ExecResult {
    pub status: RequestStatus,
    pub provenance: Option<Provenance>,
    pub compile_cycles: u64,
    pub launch_cycles: u64,
    pub instrs: u64,
    pub retries: u64,
    pub recovered: u64,
    pub injected: u64,
    pub profiles: usize,
    pub error: Option<String>,
    /// Linked-image length the compile-cost model charges against
    /// (0 on compile error). The service's threaded mode re-derives
    /// ledger charges from this after provenance reassignment.
    pub code_len: usize,
}

pub(crate) fn source_of(req: &ServeRequest) -> &str {
    match &req.payload {
        Payload::Registry { name } => {
            // The label was validated against the registry at admission;
            // find() cannot fail here.
            benchmarks::find(name).map(|b| b.source).unwrap_or("")
        }
        Payload::Source { source, .. } => source,
    }
}

/// Compile (through the shared session) and execute (on a private
/// stream) one request. `policy` already folds the service default and
/// the request's per-request override together.
///
/// Takes `&Session`: sessions are `Sync` and safe to share across a
/// worker pool — concurrent identical fingerprints dedup to a single
/// pipeline run inside the session itself.
pub fn execute(req: &ServeRequest, session: &Session, policy: LaunchPolicy) -> ExecResult {
    let (prog, provenance) = match session.compile_traced(source_of(req)) {
        Ok((p, tier)) => (
            p,
            match tier {
                CompileTier::Mem => Provenance::Mem,
                CompileTier::Disk => Provenance::Disk,
                CompileTier::Miss => Provenance::Miss,
            },
        ),
        Err(e) => {
            return ExecResult {
                status: RequestStatus::CompileError,
                provenance: Some(Provenance::Miss),
                compile_cycles: 0,
                launch_cycles: 0,
                instrs: 0,
                retries: 0,
                recovered: 0,
                injected: 0,
                profiles: 0,
                error: Some(e.to_string()),
                code_len: 0,
            }
        }
    };
    let code_len = prog.image.code.len();
    let compile_cycles = compile_cost(provenance, code_len);

    // Private execution context: a fresh device per request is the
    // isolation boundary — faults latch here and nowhere else.
    let mut stream = Stream::with_profiling(
        prog.clone(),
        session.options().device_config(),
        req.profile,
    );
    stream.set_launch_policy(policy);
    if !req.faults.is_empty() {
        stream.device_mut().gpu.faults = FaultState::new(req.faults);
    }

    let run: Result<(), String> = match &req.payload {
        Payload::Registry { name } => {
            let b = benchmarks::find(name).expect("admission validated the name");
            (b.run)(stream.device_mut())
        }
        Payload::Source { entry, grid, block, args, .. } => {
            run_source(&mut stream, entry.as_deref(), *grid, *block, args)
        }
    };

    let dev = stream.device_mut();
    let injected = dev.gpu.faults.injected() as u64;
    let retries = dev.retries_performed;
    let recovered = dev.launches_recovered;
    let device_faulted = dev.is_faulted();
    let launch_cycles = dev.total_stats.cycles;
    let instrs = dev.total_stats.instrs;
    let status = match &run {
        Ok(()) if recovered > 0 => RequestStatus::Recovered,
        Ok(()) => RequestStatus::Pass,
        Err(_) if device_faulted || stream.is_faulted() => RequestStatus::Faulted,
        Err(_) => RequestStatus::Failed,
    };
    ExecResult {
        status,
        provenance: Some(provenance),
        compile_cycles,
        launch_cycles,
        instrs,
        retries,
        recovered,
        injected,
        profiles: stream.profiles().len(),
        error: run.err(),
        code_len,
    }
}

/// Execute a kernel-file request through the genuine stream API:
/// allocate `buf:` arguments, enqueue the launch, synchronize.
fn run_source(
    stream: &mut Stream,
    entry: Option<&str>,
    grid: [u32; 3],
    block: [u32; 3],
    args: &[ArgSpec],
) -> Result<(), String> {
    let kernel = match entry {
        Some(k) => k.to_string(),
        None => stream
            .program()
            .kernels
            .first()
            .map(|k| k.name.clone())
            .ok_or("program has no kernels")?,
    };
    let mut argv = Vec::with_capacity(args.len());
    for a in args {
        match a {
            ArgSpec::Buf(bytes) => {
                let p = stream.malloc(*bytes);
                argv.push(ArgValue::Ptr(p));
            }
            ArgSpec::I32(v) => argv.push(ArgValue::I32(*v)),
            ArgSpec::F32(v) => argv.push(ArgValue::F32(*v)),
        }
    }
    stream
        .enqueue_launch(&kernel, grid, block, &argv)
        .map_err(|e| e.to_string())?;
    stream.synchronize().map_err(|e| e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::driver::VoltOptions;
    use crate::sim::{FaultKind, FaultPlan};
    use crate::transform::OptLevel;

    fn policy(retries: u32) -> LaunchPolicy {
        LaunchPolicy {
            retries,
            backoff_cycles: 0,
            watchdog_max_cycles: None,
        }
    }

    #[test]
    fn compile_cost_orders_tiers() {
        let len = 500;
        assert!(compile_cost(Provenance::Mem, len) < compile_cost(Provenance::Disk, len));
        assert!(compile_cost(Provenance::Disk, len) < compile_cost(Provenance::Miss, len));
    }

    #[test]
    fn clean_registry_request_passes_and_dedups() {
        let session = Session::new(VoltOptions::default());
        let req = ServeRequest::registry("vecadd", OptLevel::Recon);
        let r1 = execute(&req, &session, policy(0));
        assert_eq!(r1.status, RequestStatus::Pass);
        assert_eq!(r1.provenance, Some(Provenance::Miss));
        assert!(r1.launch_cycles > 0 && r1.instrs > 0);
        assert!(r1.code_len > 0);
        let r2 = execute(&req, &session, policy(0));
        assert_eq!(r2.status, RequestStatus::Pass);
        assert_eq!(r2.provenance, Some(Provenance::Mem));
        assert_eq!(r2.code_len, r1.code_len);
        assert!(r2.compile_cycles < r1.compile_cycles);
        // Same device config, same kernel, fresh device: identical
        // simulated work.
        assert_eq!(r1.launch_cycles, r2.launch_cycles);
    }

    #[test]
    fn faulty_request_recovers_within_budget_and_faults_beyond_it() {
        let session = Session::new(VoltOptions::default());
        let mut req = ServeRequest::registry("vecadd", OptLevel::Recon);
        req.faults = FaultPlan::none()
            .with(0, FaultKind::IllegalTrap { pc: None })
            .with(0, FaultKind::MemTrap { pc: None });

        // Budget >= trap count: absorbed and recovered.
        let r = execute(&req, &session, policy(2));
        assert_eq!(r.status, RequestStatus::Recovered, "{:?}", r.error);
        assert_eq!(r.injected, 2);
        assert_eq!(r.retries, 2);

        // Budget < trap count: the request faults — but only its own
        // stream; the shared session happily serves the next request.
        let r = execute(&req, &session, policy(1));
        assert_eq!(r.status, RequestStatus::Faulted);
        assert!(r.error.is_some());
        let clean = ServeRequest::registry("vecadd", OptLevel::Recon);
        let r = execute(&clean, &session, policy(0));
        assert_eq!(r.status, RequestStatus::Pass, "{:?}", r.error);
        assert_eq!(r.provenance, Some(Provenance::Mem));
    }
}
