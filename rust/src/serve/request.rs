//! Serving requests: what a client asks the batch service to do.
//!
//! A [`ServeRequest`] names either a registry benchmark (compiled from
//! its bundled source and checked by its host-side validator) or an
//! external kernel file with an explicit launch shape, plus the knobs a
//! multi-tenant service has to honor per request: ladder level, queue
//! priority, a per-request [`LaunchPolicy`](crate::runtime::LaunchPolicy)
//! override, an optional deterministic [`FaultPlan`] (chaos requests),
//! and a per-request profiler opt-in.
//!
//! Two front doors build request batches: [`parse_manifest`] (the
//! `volt serve <manifest>` text format, one request per line) and
//! [`synthetic`] (the seeded hot/cold/faulty mixed workload behind
//! `volt serve --synthetic N`).

use crate::coordinator::benchmarks::{self, Rng};
use crate::frontend::Dialect;
use crate::sim::{FaultKind, FaultPlan};
use crate::transform::OptLevel;

/// Queue class: lower sorts earlier at admission. Within a class the
/// queue is FIFO (admission order breaks ties).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    High,
    Normal,
    Low,
}

impl Priority {
    pub fn name(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }

    pub fn parse(s: &str) -> Result<Priority, String> {
        match s {
            "high" => Ok(Priority::High),
            "normal" => Ok(Priority::Normal),
            "low" => Ok(Priority::Low),
            _ => Err(format!("unknown priority '{s}' (high|normal|low)")),
        }
    }
}

/// One kernel argument in a manifest `args=` list.
#[derive(Clone, Copy, Debug)]
pub enum ArgSpec {
    /// `buf:BYTES` — allocate a device buffer of that size.
    Buf(u32),
    /// `i32:V`
    I32(i32),
    /// `f32:V`
    F32(f32),
}

impl ArgSpec {
    fn parse(s: &str) -> Result<ArgSpec, String> {
        let (kind, val) = s
            .split_once(':')
            .ok_or_else(|| format!("bad arg '{s}' (expected buf:N | i32:V | f32:V)"))?;
        match kind {
            "buf" => val
                .parse()
                .map(ArgSpec::Buf)
                .map_err(|_| format!("bad buffer size '{val}'")),
            "i32" => val
                .parse()
                .map(ArgSpec::I32)
                .map_err(|_| format!("bad i32 '{val}'")),
            "f32" => val
                .parse()
                .map(ArgSpec::F32)
                .map_err(|_| format!("bad f32 '{val}'")),
            _ => Err(format!("unknown arg kind '{kind}' (buf|i32|f32)")),
        }
    }
}

/// What the request compiles and runs.
#[derive(Clone, Debug)]
pub enum Payload {
    /// A registry benchmark: compiled from its bundled source, executed
    /// and *checked* by its host-side validator.
    Registry { name: String },
    /// An external kernel source with an explicit launch, executed
    /// through a genuine [`Stream`](crate::driver::Stream) enqueue /
    /// synchronize round (no reference validator — success means the
    /// launch completed without a fault).
    Source {
        label: String,
        source: String,
        dialect: Dialect,
        /// Kernel entry to launch (default: the program's first kernel).
        entry: Option<String>,
        grid: [u32; 3],
        block: [u32; 3],
        args: Vec<ArgSpec>,
    },
}

impl Payload {
    pub fn label(&self) -> &str {
        match self {
            Payload::Registry { name } => name,
            Payload::Source { label, .. } => label,
        }
    }
}

/// One admission-queue entry.
#[derive(Clone, Debug)]
pub struct ServeRequest {
    pub payload: Payload,
    pub opt: OptLevel,
    pub priority: Priority,
    /// Workload class tag carried into the outcome (`hot` / `cold` /
    /// `faulty` for synthetic requests, `manifest` otherwise).
    pub class: &'static str,
    /// Deterministic chaos plan armed on the request's own device.
    pub faults: FaultPlan,
    /// Per-request retry override (None = the service default).
    pub retries: Option<u32>,
    pub backoff: Option<u64>,
    /// Collect per-launch kernel profiles for this request.
    pub profile: bool,
}

impl ServeRequest {
    pub fn registry(name: &str, opt: OptLevel) -> ServeRequest {
        ServeRequest {
            payload: Payload::Registry {
                name: name.to_string(),
            },
            opt,
            priority: Priority::Normal,
            class: "manifest",
            faults: FaultPlan::none(),
            retries: None,
            backoff: None,
            profile: false,
        }
    }
}

/// Result-returning ladder parser shared by the CLI and the manifest
/// format (the CLI's `parse_level` exits; libraries need the error).
pub fn parse_opt(s: &str) -> Result<OptLevel, String> {
    match s.to_lowercase().as_str() {
        "base" => Ok(OptLevel::Base),
        "uni-hw" | "unihw" => Ok(OptLevel::UniHw),
        "uni-ann" | "uniann" => Ok(OptLevel::UniAnn),
        "uni-func" | "unifunc" => Ok(OptLevel::UniFunc),
        "zicond" => Ok(OptLevel::ZiCond),
        "recon" => Ok(OptLevel::Recon),
        "o3" => Ok(OptLevel::O3),
        _ => Err(format!(
            "unknown opt level '{s}' (base|uni-hw|uni-ann|uni-func|zicond|recon|o3)"
        )),
    }
}

fn parse_triple(s: &str, what: &str) -> Result<[u32; 3], String> {
    let parts: Vec<u32> = s.split(',').filter_map(|x| x.trim().parse().ok()).collect();
    if parts.len() != 3 || parts.iter().any(|&x| x == 0) {
        return Err(format!("bad {what} '{s}' (expected X,Y,Z with all > 0)"));
    }
    Ok([parts[0], parts[1], parts[2]])
}

/// Parse the `volt serve` manifest format. One request per line:
///
/// ```text
/// # comment
/// <registry-name | kernel-file.cl|.cu> [key=value ...] [profile]
/// ```
///
/// Keys valid on every line: `opt=LEVEL`, `prio=high|normal|low`,
/// `retries=N`, `backoff=CYCLES`, `inject=FAULTSPEC`, `repeat=N`
/// (expand the line into N identical requests). File lines additionally
/// accept `entry=KERNEL`, `grid=X,Y,Z`, `block=X,Y,Z` and
/// `args=buf:N,i32:V,f32:V,...`; file sources are read relative to the
/// manifest's directory.
pub fn parse_manifest(
    text: &str,
    base: &std::path::Path,
    default_opt: OptLevel,
) -> Result<Vec<ServeRequest>, String> {
    let mut out = vec![];
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let err = |msg: String| format!("manifest line {}: {msg}", lineno + 1);
        let mut tokens = line.split_whitespace();
        let head = tokens.next().unwrap();
        let mut req = if benchmarks::find(head).is_some() {
            ServeRequest::registry(head, default_opt)
        } else {
            let path = base.join(head);
            let source = std::fs::read_to_string(&path).map_err(|e| {
                err(format!("'{head}': not a registry benchmark or a readable file ({e})"))
            })?;
            let dialect = if head.ends_with(".cu") {
                Dialect::Cuda
            } else {
                Dialect::OpenCL
            };
            ServeRequest {
                payload: Payload::Source {
                    label: head.to_string(),
                    source,
                    dialect,
                    entry: None,
                    grid: [1, 1, 1],
                    block: [64, 1, 1],
                    args: vec![],
                },
                opt: default_opt,
                priority: Priority::Normal,
                class: "manifest",
                faults: FaultPlan::none(),
                retries: None,
                backoff: None,
                profile: false,
            }
        };
        let mut repeat = 1usize;
        for tok in tokens {
            if tok == "profile" {
                req.profile = true;
                continue;
            }
            let (key, val) = tok
                .split_once('=')
                .ok_or_else(|| err(format!("bad token '{tok}' (expected key=value)")))?;
            match key {
                "opt" => req.opt = parse_opt(val).map_err(err)?,
                "prio" => req.priority = Priority::parse(val).map_err(err)?,
                "retries" => {
                    req.retries =
                        Some(val.parse().map_err(|_| err(format!("bad retries '{val}'")))?)
                }
                "backoff" => {
                    req.backoff =
                        Some(val.parse().map_err(|_| err(format!("bad backoff '{val}'")))?)
                }
                "inject" => req.faults = FaultPlan::parse(val).map_err(err)?,
                "repeat" => {
                    repeat = val.parse().map_err(|_| err(format!("bad repeat '{val}'")))?;
                    if repeat == 0 || repeat > 10_000 {
                        return Err(err(format!("repeat={repeat} out of range (1..=10000)")));
                    }
                }
                "entry" | "grid" | "block" | "args" => {
                    let Payload::Source {
                        entry, grid, block, args, ..
                    } = &mut req.payload
                    else {
                        return Err(err(format!(
                            "'{key}=' applies only to kernel-file requests, not registry \
                             benchmark '{head}'"
                        )));
                    };
                    match key {
                        "entry" => *entry = Some(val.to_string()),
                        "grid" => *grid = parse_triple(val, "grid").map_err(err)?,
                        "block" => *block = parse_triple(val, "block").map_err(err)?,
                        _ => {
                            *args = val
                                .split(',')
                                .map(ArgSpec::parse)
                                .collect::<Result<_, _>>()
                                .map_err(err)?
                        }
                    }
                }
                _ => return Err(err(format!("unknown key '{key}'"))),
            }
        }
        for _ in 0..repeat {
            out.push(req.clone());
        }
    }
    if out.is_empty() {
        return Err("manifest contains no requests".to_string());
    }
    Ok(out)
}

/// Cheap kernels the hot-repeat class cycles through (their compiles
/// dedup in the shared cache; their validators keep sim time small).
const HOT_SET: &[&str] = &["vecadd", "saxpy", "transpose", "dotproduct"];

/// Deterministic seeded mixed workload over the registry: ~55%
/// hot-repeat (a small kernel set at the default ladder level — mem-hit
/// fodder), ~30% cold-unique (any registry kernel at any ladder level —
/// distinct fingerprints), ~15% faulty (a hot kernel with 1-2 transient
/// traps injected at launch). Priorities are seeded too. The same
/// `(count, seed)` always yields the identical request vector — the
/// determinism anchor for `BENCH_serving.json` diffs.
pub fn synthetic(count: usize, seed: u32) -> Vec<ServeRequest> {
    let registry = benchmarks::registry();
    let mut rng = Rng(seed | 1);
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let class_roll = rng.next_u32() % 100;
        let prio_roll = rng.next_u32() % 10;
        let pick = rng.next_u32() as usize;
        let mut req = if class_roll < 55 {
            let mut r = ServeRequest::registry(HOT_SET[pick % HOT_SET.len()], OptLevel::Recon);
            r.class = "hot";
            r
        } else if class_roll < 85 {
            let b = &registry[pick % registry.len()];
            let lvl = OptLevel::LADDER[rng.next_u32() as usize % OptLevel::LADDER.len()];
            let mut r = ServeRequest::registry(b.name, lvl);
            r.class = "cold";
            r
        } else {
            let mut r = ServeRequest::registry(HOT_SET[pick % HOT_SET.len()], OptLevel::Recon);
            r.class = "faulty";
            // 1 or 2 transient traps at launch: with the service's retry
            // budget >= the trap count the request recovers, otherwise it
            // faults its own stream and must not disturb neighbors.
            let traps = 1 + rng.next_u32() % 2;
            let mut plan = FaultPlan::none();
            for _ in 0..traps {
                plan = plan.with(0, FaultKind::IllegalTrap { pc: None });
            }
            r.faults = plan;
            r
        };
        req.priority = match prio_roll {
            0 | 1 => Priority::High,
            9 => Priority::Low,
            _ => Priority::Normal,
        };
        out.push(req);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_is_deterministic_and_mixed() {
        let a = synthetic(100, 7);
        let b = synthetic(100, 7);
        assert_eq!(a.len(), 100);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.payload.label(), y.payload.label());
            assert_eq!(x.opt, y.opt);
            assert_eq!(x.priority, y.priority);
            assert_eq!(x.class, y.class);
            assert_eq!(x.faults.len(), y.faults.len());
        }
        let hot = a.iter().filter(|r| r.class == "hot").count();
        let cold = a.iter().filter(|r| r.class == "cold").count();
        let faulty = a.iter().filter(|r| r.class == "faulty").count();
        assert_eq!(hot + cold + faulty, 100);
        assert!(hot > 0 && cold > 0 && faulty > 0, "{hot}/{cold}/{faulty}");
        let c = synthetic(100, 8);
        assert!(
            a.iter()
                .zip(&c)
                .any(|(x, y)| x.payload.label() != y.payload.label() || x.class != y.class),
            "different seeds must differ"
        );
    }

    #[test]
    fn manifest_parses_registry_lines() {
        let text = "# warm-up\nvecadd repeat=3 opt=o3 prio=high\nsaxpy inject=trap@0 retries=2\n";
        let reqs =
            parse_manifest(text, std::path::Path::new("."), OptLevel::Recon).unwrap();
        assert_eq!(reqs.len(), 4);
        assert_eq!(reqs[0].payload.label(), "vecadd");
        assert_eq!(reqs[0].opt, OptLevel::O3);
        assert_eq!(reqs[0].priority, Priority::High);
        assert_eq!(reqs[3].payload.label(), "saxpy");
        assert_eq!(reqs[3].faults.len(), 1);
        assert_eq!(reqs[3].retries, Some(2));
    }

    #[test]
    fn manifest_rejects_bad_lines() {
        let base = std::path::Path::new(".");
        for bad in [
            "no_such_kernel_or_file",
            "vecadd grid=1,1,1",
            "vecadd bogus=1",
            "vecadd prio=urgent",
            "",
        ] {
            assert!(
                parse_manifest(bad, base, OptLevel::Recon).is_err(),
                "accepted: {bad:?}"
            );
        }
    }
}
