//! Per-request outcomes and the aggregate serving report.
//!
//! Every admitted request yields one [`RequestOutcome`] with its
//! queue / compile / launch / total latency in *simulated* cycles and
//! the cache tier that served its compile ([`Provenance`]). The service
//! folds them into a [`ServeReport`]: p50/p95/p99 latency, throughput
//! over the simulated makespan, cache hit rates and per-device
//! utilization — rendered as text and as the `BENCH_serving.json`
//! schema (`volt-serve/v1`). Nothing in the report depends on wall
//! clock, so a fixed `(workload, seed, devices)` triple renders
//! bit-identical JSON on every rerun.

use super::request::Priority;
use crate::driver::CacheStats;

/// Which cache tier served the request's compile.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Provenance {
    /// Full pipeline run (no tier had the fingerprint).
    Miss,
    /// Served from the persistent on-disk tier.
    Disk,
    /// Served from the in-memory tier (dedup within the batch).
    Mem,
}

impl Provenance {
    pub fn name(self) -> &'static str {
        match self {
            Provenance::Miss => "miss",
            Provenance::Disk => "disk",
            Provenance::Mem => "mem",
        }
    }
}

/// Terminal state of one request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestStatus {
    /// Completed, validator clean, no faults observed.
    Pass,
    /// Completed and validator-clean after absorbing injected faults
    /// within the retry budget.
    Recovered,
    /// The request's own stream/device latched a fault (contained: no
    /// other request observed it).
    Faulted,
    /// Completed but the validator rejected the results (e.g. silent
    /// data corruption from an injected bit flip).
    Failed,
    /// The compile pipeline rejected the source.
    CompileError,
    /// Turned away at admission (queue over capacity).
    Rejected,
}

impl RequestStatus {
    pub fn name(self) -> &'static str {
        match self {
            RequestStatus::Pass => "pass",
            RequestStatus::Recovered => "recovered",
            RequestStatus::Faulted => "faulted",
            RequestStatus::Failed => "failed",
            RequestStatus::CompileError => "compile-error",
            RequestStatus::Rejected => "rejected",
        }
    }

    /// Did the request produce a correct result?
    pub fn is_ok(self) -> bool {
        matches!(self, RequestStatus::Pass | RequestStatus::Recovered)
    }
}

/// The service's record of one request.
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    /// Admission sequence number (stable across reruns).
    pub id: usize,
    pub label: String,
    pub class: &'static str,
    pub priority: Priority,
    pub status: RequestStatus,
    /// Device slot the request ran on (usize::MAX for rejected).
    pub device: usize,
    /// Compile-cache tier that served the compile (None when the
    /// request never reached the compiler).
    pub provenance: Option<Provenance>,
    /// Sim-cycles spent waiting for a device slot.
    pub queue_cycles: u64,
    /// Deterministic compile-cost model cycles (see `docs/SERVING.md`).
    pub compile_cycles: u64,
    /// Device cycles the execution consumed (includes retry backoff).
    pub launch_cycles: u64,
    /// queue + compile + launch.
    pub total_cycles: u64,
    /// Warp instructions the request executed.
    pub instrs: u64,
    pub retries: u64,
    pub recovered: u64,
    pub injected: u64,
    /// Kernel profiles collected (per-request profiler opt-in).
    pub profiles: usize,
    pub error: Option<String>,
}

/// Busy accounting for one simulated device slot.
#[derive(Clone, Debug)]
pub struct DeviceUtil {
    pub device: usize,
    pub served: u32,
    pub busy_cycles: u64,
    /// busy / makespan.
    pub utilization_pct: f64,
}

/// Aggregate serving report (`BENCH_serving.json`).
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub devices: usize,
    pub seed: u32,
    pub outcomes: Vec<RequestOutcome>,
    pub device_util: Vec<DeviceUtil>,
    /// Virtual-time span from first dispatch to last completion.
    pub makespan_cycles: u64,
    /// Compile-cache counters summed over the service's session pool.
    pub cache: CacheStats,
    /// Corrupt disk entries quarantined under the cache directory.
    pub quarantined: usize,
}

/// Nearest-rank percentile of a sorted sample (`p` in 0..=100).
pub fn percentile(sorted: &[u64], p: u64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = (p * sorted.len() as u64).div_ceil(100).max(1) as usize;
    sorted[rank.min(sorted.len()) - 1]
}

impl ServeReport {
    /// Latencies (total cycles) of every request that reached a device,
    /// sorted ascending.
    fn sorted_totals(&self) -> Vec<u64> {
        let mut v: Vec<u64> = self
            .outcomes
            .iter()
            .filter(|o| o.status != RequestStatus::Rejected)
            .map(|o| o.total_cycles)
            .collect();
        v.sort_unstable();
        v
    }

    pub fn completed(&self) -> usize {
        self.outcomes
            .iter()
            .filter(|o| o.status != RequestStatus::Rejected)
            .count()
    }

    pub fn count(&self, s: RequestStatus) -> usize {
        self.outcomes.iter().filter(|o| o.status == s).count()
    }

    /// (p50, p95, p99) of total latency over completed requests.
    pub fn latency_percentiles(&self) -> (u64, u64, u64) {
        let v = self.sorted_totals();
        (percentile(&v, 50), percentile(&v, 95), percentile(&v, 99))
    }

    /// Completed requests per million simulated device-cycles.
    pub fn throughput_per_mcycle(&self) -> f64 {
        if self.makespan_cycles == 0 {
            0.0
        } else {
            self.completed() as f64 * 1e6 / self.makespan_cycles as f64
        }
    }

    /// Requests whose validator failed (or stream faulted) without any
    /// injected fault — must be zero for a healthy service.
    pub fn clean_failures(&self) -> usize {
        self.outcomes
            .iter()
            .filter(|o| o.injected == 0 && o.status != RequestStatus::Rejected)
            .filter(|o| !o.status.is_ok())
            .count()
    }

    pub fn render_text(&self) -> String {
        let (p50, p95, p99) = self.latency_percentiles();
        let mut out = String::new();
        out.push_str(&format!(
            "serve: {} request(s) on {} device(s), seed {}\n",
            self.outcomes.len(),
            self.devices,
            self.seed
        ));
        out.push_str(&format!(
            "  status: pass={} recovered={} faulted={} failed={} compile-error={} rejected={}\n",
            self.count(RequestStatus::Pass),
            self.count(RequestStatus::Recovered),
            self.count(RequestStatus::Faulted),
            self.count(RequestStatus::Failed),
            self.count(RequestStatus::CompileError),
            self.count(RequestStatus::Rejected),
        ));
        out.push_str(&format!(
            "  latency (cycles): p50={p50} p95={p95} p99={p99}\n"
        ));
        out.push_str(&format!(
            "  throughput: {:.3} req/Mcycle over a {}-cycle makespan\n",
            self.throughput_per_mcycle(),
            self.makespan_cycles
        ));
        out.push_str(&format!(
            "  cache: mem-hits={} misses={} disk-hits={} corrupt={} evicted={} quarantined={}\n",
            self.cache.hits,
            self.cache.misses,
            self.cache.disk_hits,
            self.cache.disk_corrupt,
            self.cache.disk_evicted,
            self.quarantined,
        ));
        for d in &self.device_util {
            out.push_str(&format!(
                "  device {}: served={} busy={} cycles ({:.1}% utilized)\n",
                d.device, d.served, d.busy_cycles, d.utilization_pct
            ));
        }
        out
    }

    /// The `volt-serve/v1` JSON document. Pure function of the
    /// outcomes — no timestamps, no wall clock, no map iteration — so
    /// identical runs serialize byte-identically.
    pub fn render_json(&self) -> String {
        let (p50, p95, p99) = self.latency_percentiles();
        let mut s = String::from("{\"schema\":\"volt-serve/v1\"");
        s.push_str(&format!(",\"devices\":{}", self.devices));
        s.push_str(&format!(",\"seed\":{}", self.seed));
        s.push_str(&format!(",\"requests\":{}", self.outcomes.len()));
        s.push_str(&format!(",\"completed\":{}", self.completed()));
        s.push_str(&format!(
            ",\"status\":{{\"pass\":{},\"recovered\":{},\"faulted\":{},\"failed\":{},\
             \"compile_error\":{},\"rejected\":{}}}",
            self.count(RequestStatus::Pass),
            self.count(RequestStatus::Recovered),
            self.count(RequestStatus::Faulted),
            self.count(RequestStatus::Failed),
            self.count(RequestStatus::CompileError),
            self.count(RequestStatus::Rejected),
        ));
        s.push_str(&format!(
            ",\"latency_cycles\":{{\"p50\":{p50},\"p95\":{p95},\"p99\":{p99}}}"
        ));
        s.push_str(&format!(
            ",\"throughput_per_mcycle\":{:.3}",
            self.throughput_per_mcycle()
        ));
        s.push_str(&format!(",\"makespan_cycles\":{}", self.makespan_cycles));
        s.push_str(&format!(
            ",\"cache\":{{\"mem_hits\":{},\"misses\":{},\"disk_hits\":{},\"disk_corrupt\":{},\
             \"disk_evicted\":{},\"quarantined\":{}}}",
            self.cache.hits,
            self.cache.misses,
            self.cache.disk_hits,
            self.cache.disk_corrupt,
            self.cache.disk_evicted,
            self.quarantined,
        ));
        s.push_str(",\"device_util\":[");
        for (i, d) in self.device_util.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"device\":{},\"served\":{},\"busy_cycles\":{},\"utilization_pct\":{:.1}}}",
                d.device, d.served, d.busy_cycles, d.utilization_pct
            ));
        }
        s.push_str("],\"outcomes\":[");
        for (i, o) in self.outcomes.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"id\":{},\"label\":\"{}\",\"class\":\"{}\",\"priority\":\"{}\",\
                 \"status\":\"{}\",\"device\":{},\"provenance\":{},\"queue_cycles\":{},\
                 \"compile_cycles\":{},\"launch_cycles\":{},\"total_cycles\":{},\
                 \"instrs\":{},\"retries\":{},\"recovered\":{},\"injected\":{},\
                 \"profiles\":{},\"error\":{}}}",
                o.id,
                esc(&o.label),
                o.class,
                o.priority.name(),
                o.status.name(),
                if o.device == usize::MAX {
                    -1i64
                } else {
                    o.device as i64
                },
                match o.provenance {
                    Some(p) => format!("\"{}\"", p.name()),
                    None => "null".to_string(),
                },
                o.queue_cycles,
                o.compile_cycles,
                o.launch_cycles,
                o.total_cycles,
                o.instrs,
                o.retries,
                o.recovered,
                o.injected,
                o.profiles,
                match &o.error {
                    Some(e) => format!("\"{}\"", esc(e)),
                    None => "null".to_string(),
                },
            ));
        }
        s.push_str("]}");
        s
    }
}

/// Minimal JSON string escaping (labels and error messages may carry
/// quotes/backslashes from typed error formatting).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 50), 50);
        assert_eq!(percentile(&v, 95), 95);
        assert_eq!(percentile(&v, 99), 99);
        assert_eq!(percentile(&v, 100), 100);
        assert_eq!(percentile(&[42], 50), 42);
        assert_eq!(percentile(&[], 99), 0);
        // Ranks round up: p50 of [1,2,3] is the 2nd value.
        assert_eq!(percentile(&[1, 2, 3], 50), 2);
    }

    #[test]
    fn json_escapes_and_validates() {
        let rep = ServeReport {
            devices: 2,
            seed: 7,
            outcomes: vec![RequestOutcome {
                id: 0,
                label: "we\"ird\\name".into(),
                class: "manifest",
                priority: Priority::Normal,
                status: RequestStatus::Faulted,
                device: 1,
                provenance: Some(Provenance::Miss),
                queue_cycles: 0,
                compile_cycles: 10,
                launch_cycles: 20,
                total_cycles: 30,
                instrs: 5,
                retries: 1,
                recovered: 0,
                injected: 2,
                profiles: 0,
                error: Some("trap\nat \"pc 3\"".into()),
            }],
            device_util: vec![DeviceUtil {
                device: 0,
                served: 1,
                busy_cycles: 30,
                utilization_pct: 100.0,
            }],
            makespan_cycles: 30,
            cache: CacheStats::default(),
            quarantined: 0,
        };
        let json = rep.render_json();
        crate::prof::validate_json(&json).unwrap();
        assert!(json.contains("\"schema\":\"volt-serve/v1\""));
        assert!(json.contains("\\\"pc 3\\\""));
        let text = rep.render_text();
        assert!(text.contains("faulted=1"), "{text}");
    }
}
