//! The Vortex-style target ISA (paper §2.4 Table 2 + §4.4 "ISA table
//! extension").
//!
//! A RV32IMF-like scalar core extended with the Vortex SIMT operations:
//! `vx_tmc`, `vx_wspawn`, `vx_split`, `vx_join`, `vx_pred`, `vx_barrier`,
//! `vx_active_threads` (here `MASK`), plus the §5.3 case-study extensions
//! `vx_shfl`, `vx_vote.*` and `vx_cmov` (the ZiCond CMOV). Instructions use
//! a regular 64-bit encoding (op/rd/rs1/rs2 in the low word, a 32-bit
//! immediate in the high word) — the semantic contract, not the RISC-V bit
//! layout, is what the compiler pipeline targets (see DESIGN.md
//! §Vortex-ISA-adaptation).
//!
//! `vx_split` packs two instruction indices in its immediate: the low half
//! is the reconvergence (join) index pushed on the IPDOM stack, the high
//! half the else-target (NVIDIA-SSY-style recorded reconvergence PC).

/// Register indices: 0..32 integer (x0 hardwired zero), 32..64 float.
pub const NUM_REGS: u8 = 64;
pub const X0: u8 = 0;
/// Return address (x1).
pub const RA: u8 = 1;
/// Stack pointer (x2).
pub const SP: u8 = 2;
/// First integer/float argument registers (x10.. / f10..).
pub const A0: u8 = 10;
pub const FA0: u8 = 32 + 10;
/// Integer scratch registers reserved for spill reloads and crt0.
pub const T5: u8 = 30;
pub const T6: u8 = 31;
pub const FT5: u8 = 32 + 30;

pub fn is_float_reg(r: u8) -> bool {
    r >= 32
}

/// CSR identifiers (immediate of `CSRR`).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum CsrId {
    LaneId = 0,
    WarpId = 1,
    CoreId = 2,
    NumThreads = 3,
    NumWarps = 4,
    NumCores = 5,
}

impl CsrId {
    /// Decode a CSR index. Unknown indices are `None` — the simulator
    /// traps on them (a silently-misdecoded CSR read is a miscompile
    /// masquerading as a hardware value).
    pub fn from_u32(v: u32) -> Option<CsrId> {
        match v {
            0 => Some(CsrId::LaneId),
            1 => Some(CsrId::WarpId),
            2 => Some(CsrId::CoreId),
            3 => Some(CsrId::NumThreads),
            4 => Some(CsrId::NumWarps),
            5 => Some(CsrId::NumCores),
            _ => None,
        }
    }
}

macro_rules! ops {
    ($($name:ident = $code:expr => $mnem:expr ; $class:ident),+ $(,)?) => {
        /// Opcode table — the "ISA description table" of §4.4.
        #[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
        #[repr(u8)]
        pub enum Op { $($name = $code),+ }

        impl Op {
            pub fn mnemonic(self) -> &'static str {
                match self { $(Op::$name => $mnem),+ }
            }
            pub fn from_u8(v: u8) -> Option<Op> {
                match v { $($code => Some(Op::$name),)+ _ => None }
            }
            /// Functional class, used for timing and hazard checks.
            pub fn class(self) -> OpClass {
                match self { $(Op::$name => OpClass::$class),+ }
            }
            pub const ALL: &'static [Op] = &[$(Op::$name),+];
        }
    };
}

/// Functional-unit class (drives the simulator timing model).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum OpClass {
    Alu,
    Mul,
    Div,
    Fpu,
    FDiv,
    /// transcendental (software-library ops modeled as SFU)
    Sfu,
    Mem,
    Branch,
    /// Vortex divergence / warp control (executes on the SFU, paper Fig. 3)
    Vx,
    Sys,
}

ops! {
    NOP    = 0x00 => "nop"; Alu,
    LI     = 0x01 => "li"; Alu,
    MOV    = 0x02 => "mv"; Alu,
    ADD    = 0x03 => "add"; Alu,
    SUB    = 0x04 => "sub"; Alu,
    MUL    = 0x05 => "mul"; Mul,
    DIV    = 0x06 => "div"; Div,
    DIVU   = 0x07 => "divu"; Div,
    REM    = 0x08 => "rem"; Div,
    REMU   = 0x09 => "remu"; Div,
    AND    = 0x0a => "and"; Alu,
    OR     = 0x0b => "or"; Alu,
    XOR    = 0x0c => "xor"; Alu,
    SLL    = 0x0d => "sll"; Alu,
    SRL    = 0x0e => "srl"; Alu,
    SRA    = 0x0f => "sra"; Alu,
    MIN    = 0x10 => "min"; Alu,
    MAX    = 0x11 => "max"; Alu,
    ADDI   = 0x12 => "addi"; Alu,
    ANDI   = 0x13 => "andi"; Alu,
    ORI    = 0x14 => "ori"; Alu,
    XORI   = 0x15 => "xori"; Alu,
    SLLI   = 0x16 => "slli"; Alu,
    SRLI   = 0x17 => "srli"; Alu,
    SRAI   = 0x18 => "srai"; Alu,
    SEQ    = 0x19 => "seq"; Alu,
    SNE    = 0x1a => "sne"; Alu,
    SLT    = 0x1b => "slt"; Alu,
    SLE    = 0x1c => "sle"; Alu,
    SLTU   = 0x1d => "sltu"; Alu,
    SGEU   = 0x1e => "sgeu"; Alu,
    LW     = 0x20 => "lw"; Mem,
    SW     = 0x21 => "sw"; Mem,
    FADD   = 0x30 => "fadd.s"; Fpu,
    FSUB   = 0x31 => "fsub.s"; Fpu,
    FMUL   = 0x32 => "fmul.s"; Fpu,
    FDIV   = 0x33 => "fdiv.s"; FDiv,
    FMIN   = 0x34 => "fmin.s"; Fpu,
    FMAX   = 0x35 => "fmax.s"; Fpu,
    FSQRT  = 0x36 => "fsqrt.s"; FDiv,
    FNEG   = 0x37 => "fneg.s"; Fpu,
    FABS   = 0x38 => "fabs.s"; Fpu,
    FEXP   = 0x39 => "fexp.s"; Sfu,
    FLOG   = 0x3a => "flog.s"; Sfu,
    FFLOOR = 0x3b => "ffloor.s"; Fpu,
    FCVTWS = 0x3c => "fcvt.w.s"; Fpu,
    FCVTSW = 0x3d => "fcvt.s.w"; Fpu,
    FMVXW  = 0x3e => "fmv.x.w"; Alu,
    FMVWX  = 0x3f => "fmv.w.x"; Alu,
    FEQ    = 0x40 => "feq.s"; Fpu,
    FLT    = 0x41 => "flt.s"; Fpu,
    FLE    = 0x42 => "fle.s"; Fpu,
    FNE    = 0x43 => "fne.s"; Fpu,
    FGT    = 0x44 => "fgt.s"; Fpu,
    FGE    = 0x45 => "fge.s"; Fpu,
    BEQZ   = 0x50 => "beqz"; Branch,
    BNEZ   = 0x51 => "bnez"; Branch,
    J      = 0x52 => "j"; Branch,
    JAL    = 0x53 => "jal"; Branch,
    JALR   = 0x54 => "jalr"; Branch,
    ECALL  = 0x55 => "ecall"; Sys,
    CSRR   = 0x56 => "csrr"; Sys,
    AMOADD = 0x60 => "amoadd.w"; Mem,
    AMOAND = 0x61 => "amoand.w"; Mem,
    AMOOR  = 0x62 => "amoor.w"; Mem,
    AMOXOR = 0x63 => "amoxor.w"; Mem,
    AMOMIN = 0x64 => "amomin.w"; Mem,
    AMOMAX = 0x65 => "amomax.w"; Mem,
    AMOSWAP= 0x66 => "amoswap.w"; Mem,
    AMOCAS = 0x67 => "amocas.w"; Mem,
    // ---- Vortex ISA extensions (Table 2) ----
    TMC    = 0x70 => "vx_tmc"; Vx,
    WSPAWN = 0x71 => "vx_wspawn"; Vx,
    SPLIT  = 0x72 => "vx_split"; Vx,
    SPLITN = 0x73 => "vx_split.n"; Vx,
    JOIN   = 0x74 => "vx_join"; Vx,
    PRED   = 0x75 => "vx_pred"; Vx,
    BAR    = 0x76 => "vx_bar"; Vx,
    MASK   = 0x77 => "vx_active_threads"; Vx,
    // ---- §5.3 case-study extensions ----
    SHFL   = 0x78 => "vx_shfl"; Vx,
    VOTEALL= 0x79 => "vx_vote.all"; Vx,
    VOTEANY= 0x7a => "vx_vote.any"; Vx,
    BALLOT = 0x7b => "vx_vote.ballot"; Vx,
    CMOV   = 0x7c => "vx_cmov"; Alu,
    PRINTI = 0x7d => "vx_printi"; Sys,
    PRINTF = 0x7e => "vx_printf"; Sys,
}

/// A fully-resolved machine instruction (also the decode target).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MachInst {
    pub op: Op,
    pub rd: u8,
    pub rs1: u8,
    pub rs2: u8,
    pub imm: i32,
}

impl MachInst {
    pub fn encode(&self) -> u64 {
        let lo = (self.op as u64)
            | ((self.rd as u64) << 8)
            | ((self.rs1 as u64) << 16)
            | ((self.rs2 as u64) << 24);
        lo | ((self.imm as u32 as u64) << 32)
    }

    pub fn decode(w: u64) -> Option<MachInst> {
        Some(MachInst {
            op: Op::from_u8((w & 0xff) as u8)?,
            rd: ((w >> 8) & 0xff) as u8,
            rs1: ((w >> 16) & 0xff) as u8,
            rs2: ((w >> 24) & 0xff) as u8,
            imm: (w >> 32) as u32 as i32,
        })
    }

    /// Split: pack (else_idx, join_idx) into imm.
    pub fn split_targets(imm: i32) -> (u32, u32) {
        let u = imm as u32;
        (u >> 16, u & 0xffff)
    }
    pub fn pack_split(else_idx: u32, join_idx: u32) -> i32 {
        assert!(else_idx < 0x10000 && join_idx < 0x10000, "program too large for split encoding");
        ((else_idx << 16) | join_idx) as i32
    }
}

/// Disassemble one instruction.
pub fn disasm(i: &MachInst) -> String {
    let r = |x: u8| {
        if is_float_reg(x) {
            format!("f{}", x - 32)
        } else {
            format!("x{}", x)
        }
    };
    match i.op.class() {
        OpClass::Branch => match i.op {
            Op::J => format!("j {}", i.imm),
            Op::JAL => format!("jal {}, {}", r(i.rd), i.imm),
            Op::JALR => format!("jalr {}, {}, {}", r(i.rd), r(i.rs1), i.imm),
            _ => format!("{} {}, {}", i.op.mnemonic(), r(i.rs1), i.imm),
        },
        _ => match i.op {
            Op::NOP | Op::JOIN => i.op.mnemonic().to_string(),
            Op::LI => format!("li {}, {}", r(i.rd), i.imm),
            Op::MOV | Op::FNEG | Op::FABS | Op::FSQRT | Op::FEXP | Op::FLOG | Op::FFLOOR
            | Op::FCVTWS | Op::FCVTSW | Op::FMVXW | Op::FMVWX => {
                format!("{} {}, {}", i.op.mnemonic(), r(i.rd), r(i.rs1))
            }
            Op::LW => format!("lw {}, {}({})", r(i.rd), i.imm, r(i.rs1)),
            Op::SW => format!("sw {}, {}({})", r(i.rs2), i.imm, r(i.rs1)),
            Op::ADDI | Op::ANDI | Op::ORI | Op::XORI | Op::SLLI | Op::SRLI | Op::SRAI => {
                format!("{} {}, {}, {}", i.op.mnemonic(), r(i.rd), r(i.rs1), i.imm)
            }
            Op::ECALL => format!("ecall {}", i.imm),
            Op::CSRR => match CsrId::from_u32(i.imm as u32) {
                Some(id) => format!("csrr {}, {:?}", r(i.rd), id),
                None => format!("csrr {}, ?{}", r(i.rd), i.imm),
            },
            Op::TMC => format!("vx_tmc {}", r(i.rs1)),
            Op::WSPAWN => format!("vx_wspawn {}, @{}", r(i.rs1), i.imm),
            Op::SPLIT | Op::SPLITN => {
                let (e, j) = MachInst::split_targets(i.imm);
                format!("{} {}, else=@{}, join=@{}", i.op.mnemonic(), r(i.rs1), e, j)
            }
            Op::PRED => format!("vx_pred {}, {}, exit=@{}", r(i.rs1), r(i.rs2), i.imm),
            // Read-modify-write memory ops: the address is rs1 (shown in
            // parens), the operand rs2, and rd receives the OLD memory
            // value. AMOCAS additionally reads rd as the expected value.
            Op::AMOADD | Op::AMOAND | Op::AMOOR | Op::AMOXOR | Op::AMOMIN | Op::AMOMAX
            | Op::AMOSWAP => {
                format!("{} {}, {}, ({})", i.op.mnemonic(), r(i.rd), r(i.rs2), r(i.rs1))
            }
            Op::AMOCAS => format!(
                "amocas.w {}, {}, ({}), expect={}",
                r(i.rd),
                r(i.rs2),
                r(i.rs1),
                r(i.rd)
            ),
            // ZiCond conditional move: rd is also a source (kept when the
            // condition is false) — the contract regalloc's dedicated T7
            // scratch exists for.
            Op::CMOV => format!(
                "vx_cmov {}, {}, {}, old={}",
                r(i.rd),
                r(i.rs1),
                r(i.rs2),
                r(i.rd)
            ),
            Op::BAR => format!("vx_bar {}, {}", i.imm, r(i.rs1)),
            Op::MASK => format!("vx_active_threads {}", r(i.rd)),
            Op::PRINTI | Op::PRINTF => format!("{} {}", i.op.mnemonic(), r(i.rs1)),
            _ => format!(
                "{} {}, {}, {}",
                i.op.mnemonic(),
                r(i.rd),
                r(i.rs1),
                r(i.rs2)
            ),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_round_trip() {
        for &op in Op::ALL {
            let i = MachInst {
                op,
                rd: 7,
                rs1: 33,
                rs2: 63,
                imm: -12345,
            };
            assert_eq!(MachInst::decode(i.encode()), Some(i));
        }
    }

    #[test]
    fn split_target_packing() {
        let imm = MachInst::pack_split(1234, 777);
        assert_eq!(MachInst::split_targets(imm), (1234, 777));
    }

    #[test]
    fn opcode_table_unique() {
        let mut seen = std::collections::HashSet::new();
        for &op in Op::ALL {
            assert!(seen.insert(op as u8), "duplicate opcode {op:?}");
        }
        assert!(Op::from_u8(0x72) == Some(Op::SPLIT));
        assert_eq!(Op::SPLIT.class(), OpClass::Vx);
        assert_eq!(Op::FEXP.class(), OpClass::Sfu);
    }

    #[test]
    fn csr_decode_is_fallible() {
        assert_eq!(CsrId::from_u32(0), Some(CsrId::LaneId));
        assert_eq!(CsrId::from_u32(5), Some(CsrId::NumCores));
        assert_eq!(CsrId::from_u32(6), None);
        assert_eq!(CsrId::from_u32(u32::MAX), None);
        let bad = MachInst {
            op: Op::CSRR,
            rd: 5,
            rs1: 0,
            rs2: 0,
            imm: 99,
        };
        assert_eq!(disasm(&bad), "csrr x5, ?99");
    }

    #[test]
    fn disasm_smoke() {
        let i = MachInst {
            op: Op::LW,
            rd: 5,
            rs1: 2,
            rs2: 0,
            imm: 16,
        };
        assert_eq!(disasm(&i), "lw x5, 16(x2)");
        let s = MachInst {
            op: Op::SPLIT,
            rd: 0,
            rs1: 9,
            rs2: 0,
            imm: MachInst::pack_split(20, 30),
        };
        assert!(disasm(&s).contains("else=@20"));
    }

    /// Read-modify-write ops disassemble with their rd-is-also-source /
    /// rd-gets-old-value contracts spelled out instead of the generic
    /// 3-register form.
    #[test]
    fn disasm_shows_rmw_semantics() {
        let cmov = MachInst {
            op: Op::CMOV,
            rd: 5,
            rs1: 6,
            rs2: 7,
            imm: 0,
        };
        assert_eq!(disasm(&cmov), "vx_cmov x5, x6, x7, old=x5");
        let amo = MachInst {
            op: Op::AMOADD,
            rd: 5,
            rs1: 6,
            rs2: 7,
            imm: 0,
        };
        assert_eq!(disasm(&amo), "amoadd.w x5, x7, (x6)");
        let cas = MachInst {
            op: Op::AMOCAS,
            rd: 5,
            rs1: 6,
            rs2: 7,
            imm: 0,
        };
        assert_eq!(disasm(&cas), "amocas.w x5, x7, (x6), expect=x5");
        for op in [Op::AMOAND, Op::AMOOR, Op::AMOXOR, Op::AMOMIN, Op::AMOMAX, Op::AMOSWAP] {
            let i = MachInst {
                op,
                rd: 3,
                rs1: 4,
                rs2: 5,
                imm: 0,
            };
            let d = disasm(&i);
            assert!(
                d.contains("(x4)") && d.contains("x3") && d.contains("x5"),
                "{op:?}: {d}"
            );
        }
    }
}
