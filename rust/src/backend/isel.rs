//! Instruction selection: SSA IR → virtual-register MIR.
//!
//! Phis are destructed into parallel copies at predecessor ends (critical
//! edges are split first — precisely, without disturbing the `SplitBr`
//! reconvergence field). Divergence operations lower 1:1 onto the Vortex
//! ISA extensions.
//!
//! Selection is target-checked: ops gated on an ISA feature the
//! [`crate::target::TargetDesc`] does not declare are refused with a
//! typed [`BackendError`] (select→branch legalization happens in the
//! middle-end, *before* divergence management — see
//! `transform::pass::OptConfig::features`; there is no post-isel
//! fallback for `vx_shfl`/`vx_vote`).

use super::emit::BackendError;
use super::isa::{Op, A0, FA0, RA, SP};
use super::mir::{MBlock, MFunction, MInst, MReg, NONE};
use crate::ir::*;
use std::collections::HashMap;

/// Split critical edges without touching `SplitBr::ipdom`.
fn split_critical_edges(f: &mut Function) {
    loop {
        let preds = f.preds();
        let mut work: Option<(BlockId, usize, BlockId)> = None; // (block, succ field index, succ)
        'outer: for b in f.block_ids() {
            let succs = f.succs(b);
            if succs.len() < 2 {
                continue;
            }
            for (i, &s) in succs.iter().enumerate() {
                if preds[s.idx()].len() > 1 {
                    work = Some((b, i, s));
                    break 'outer;
                }
            }
        }
        let Some((b, field, s)) = work else { return };
        let stub = f.add_block("crit");
        f.push_inst(stub, InstKind::Br { target: s }, Type::Void);
        let t = f.term(b);
        // Replace exactly the `field`-th successor.
        match &mut f.inst_mut(t).kind {
            InstKind::CondBr { t, f: fb, .. } => {
                if field == 0 {
                    *t = stub;
                } else {
                    *fb = stub;
                }
            }
            InstKind::SplitBr { then_b, else_b, .. } => {
                if field == 0 {
                    *then_b = stub;
                } else {
                    *else_b = stub;
                }
            }
            InstKind::PredBr { body, exit, .. } => {
                if field == 0 {
                    *body = stub;
                } else {
                    *exit = stub;
                }
            }
            _ => unreachable!(),
        }
        // Rewrite phis in s: incoming from b (this edge) -> stub. With
        // multiple parallel edges b->s the first matching incoming is
        // rewritten; remaining ones are handled by later iterations.
        let insts = f.blocks[s.idx()].insts.clone();
        for i in insts {
            if let InstKind::Phi { incs } = &mut f.insts[i.idx()].kind {
                if let Some(e) = incs.iter_mut().find(|(p, _)| *p == b) {
                    e.0 = stub;
                }
            } else {
                break;
            }
        }
    }
}

pub struct IselResult {
    pub mf: MFunction,
}

/// Refuse selected MIR that uses an extension the target lacks. The
/// middle-end keeps selects/warp intrinsics out of reach on such targets
/// when driven through `VoltOptions`; this is the hard backstop for
/// hand-built IR or mismatched configurations.
fn check_target_support(
    mf: &MFunction,
    target: &crate::target::TargetDesc,
) -> Result<(), BackendError> {
    for b in &mf.blocks {
        for i in &b.insts {
            if !target.supports_op(i.op) {
                let gate = crate::target::Features::gate_name(i.op).unwrap_or("?");
                let hint = match i.op {
                    Op::CMOV => {
                        " (selects must be legalized to branches in the middle-end: \
                         compile with OptConfig.features matching the target)"
                    }
                    Op::SHFL | Op::VOTEALL | Op::VOTEANY | Op::BALLOT => {
                        " (no hardware fallback: recompile with warp_hw = false \
                         for the shared-memory software emulation)"
                    }
                    _ => "",
                };
                return Err(BackendError::new(
                    Some(mf.name.as_str()),
                    format!(
                        "'{}' selected but target '{}' lacks the '{gate}' extension{hint}",
                        i.op.mnemonic(),
                        target.name
                    ),
                ));
            }
        }
    }
    Ok(())
}

pub fn select_function(
    m: &Module,
    fid: FuncId,
    layout: &super::emit::LayoutInfo,
    opts: &super::emit::BackendOptions,
) -> Result<MFunction, BackendError> {
    let mut f = m.func(fid).clone();
    f.remove_unreachable();
    split_critical_edges(&mut f);
    let nblocks = f.blocks.len();
    let mut mf = MFunction {
        name: f.name.clone(),
        blocks: (0..nblocks)
            .map(|i| MBlock {
                insts: vec![],
                name: f.blocks[i].name.clone(),
            })
            .collect(),
        vreg_float: vec![],
        frame_size: 0,
        spill_size: 0,
        has_calls: false,
        local_mem_size: f.local_mem_size,
    };

    // Pre-assign vregs for every value-producing instruction.
    let mut vmap: HashMap<InstId, MReg> = HashMap::new();
    let mut alloca_off: HashMap<InstId, u32> = HashMap::new();
    for (idx, inst) in f.insts.iter().enumerate() {
        if inst.dead {
            continue;
        }
        let id = InstId(idx as u32);
        if let InstKind::Alloca { size } = inst.kind {
            alloca_off.insert(id, mf.frame_size);
            mf.frame_size += (size + 3) & !3;
        }
        if inst.ty != Type::Void {
            let r = mf.new_vreg(inst.ty == Type::F32);
            vmap.insert(id, r);
        }
    }
    // Argument vregs, copied from the ABI registers at entry.
    let mut arg_regs: Vec<MReg> = vec![];
    {
        let entry = f.entry.idx();
        let mut ni = 0u8;
        let mut nf = 0u8;
        for p in &f.params {
            let is_f = p.ty == Type::F32;
            let v = mf.new_vreg(is_f);
            let phys = if is_f {
                let r = MReg::phys(FA0 + nf);
                nf += 1;
                r
            } else {
                let r = MReg::phys(A0 + ni);
                ni += 1;
                r
            };
            assert!(ni <= 8 && nf <= 8, "too many parameters for the ABI");
            mf.blocks[entry].insts.push(MInst::mv(v, phys));
            arg_regs.push(v);
        }
    }

    let mut ctx = Ctx {
        m,
        f: &f,
        mf,
        vmap,
        arg_regs,
        alloca_off,
        layout,
        cur: 0,
    };
    for b in f.block_ids() {
        ctx.cur = b.idx();
        let insts = f.blocks[b.idx()].insts.clone();
        for &id in &insts {
            let loc = ctx.f.inst(id).loc;
            let start = ctx.mf.blocks[ctx.cur].insts.len();
            ctx.lower(id);
            // Everything this IR instruction selected into (including
            // operand materialization and phi copies) inherits its
            // source location.
            if loc.is_some() {
                for mi in ctx.mf.blocks[ctx.cur].insts[start..].iter_mut() {
                    if mi.loc.is_none() {
                        mi.loc = loc;
                    }
                }
            }
        }
    }
    check_target_support(&ctx.mf, &opts.target)?;
    Ok(ctx.mf)
}

struct Ctx<'a> {
    m: &'a Module,
    f: &'a Function,
    mf: MFunction,
    vmap: HashMap<InstId, MReg>,
    arg_regs: Vec<MReg>,
    alloca_off: HashMap<InstId, u32>,
    layout: &'a super::emit::LayoutInfo,
    cur: usize,
}

impl<'a> Ctx<'a> {
    fn push(&mut self, i: MInst) {
        self.mf.blocks[self.cur].insts.push(i);
    }

    fn reg(&mut self, v: Val) -> MReg {
        match v {
            Val::Inst(i) => self.vmap[&i],
            Val::Arg(a) => self.arg_regs[a as usize],
            Val::I(x, _) => {
                let r = self.mf.new_vreg(false);
                self.push(MInst::li(r, x as i32 as i64));
                r
            }
            Val::F(bits) => {
                let r = self.mf.new_vreg(true);
                self.push(MInst::li(r, bits as i64));
                r
            }
            Val::G(g) => {
                let r = self.mf.new_vreg(false);
                let addr = *self
                    .layout
                    .addr
                    .get(&g)
                    .unwrap_or_else(|| panic!("global g{} not laid out", g.0));
                self.push(MInst::li(r, addr as i64));
                if self.layout.core_banked.contains(&g) {
                    // Shared memory mapped onto global memory (Fig. 10):
                    // address = base + core_id * bank_stride.
                    let cid = self.mf.new_vreg(false);
                    self.push(MInst::rri(Op::CSRR, cid, NONE, 2)); // core_id
                    let stride = self.mf.new_vreg(false);
                    self.push(MInst::li(stride, self.layout.bank_stride as i64));
                    let off = self.mf.new_vreg(false);
                    self.push(MInst::rrr(Op::MUL, off, cid, stride));
                    let fin = self.mf.new_vreg(false);
                    self.push(MInst::rrr(Op::ADD, fin, r, off));
                    return fin;
                }
                r
            }
        }
    }

    /// Address lowering: returns (base reg, displacement).
    fn addr(&mut self, ptr: Val) -> (MReg, i64) {
        if let Val::Inst(i) = ptr {
            if let InstKind::Gep {
                base,
                index: Val::I(c, _),
                scale,
                disp,
            } = self.f.inst(i).kind
            {
                let b = self.reg(base);
                return (b, c * scale as i64 + disp as i64);
            }
        }
        (self.reg(ptr), 0)
    }

    /// Emit the parallel phi copies for every successor of the current
    /// block (critical edges are already split).
    fn phi_copies(&mut self, b: BlockId) {
        let mut pairs: Vec<(MReg, Val)> = vec![];
        for s in self.f.succs(b) {
            for &i in &self.f.blocks[s.idx()].insts {
                if let InstKind::Phi { incs } = &self.f.inst(i).kind {
                    if let Some((_, v)) = incs.iter().find(|(p, _)| *p == b) {
                        pairs.push((self.vmap[&i], *v));
                    }
                } else {
                    break;
                }
            }
        }
        if pairs.is_empty() {
            return;
        }
        // Topological emission with cycle breaking via a temp.
        let dsts: Vec<MReg> = pairs.iter().map(|(d, _)| *d).collect();
        let mut remaining: Vec<(MReg, Val)> = pairs;
        let mut emitted: Vec<MReg> = vec![];
        while !remaining.is_empty() {
            // Find a pair whose dst is not a source of any other remaining pair.
            let idx = remaining.iter().position(|(d, _)| {
                !remaining.iter().any(|(_, s2)| match s2 {
                    Val::Inst(si) => self.vmap.get(si) == Some(d),
                    Val::Arg(a) => self.arg_regs.get(*a as usize) == Some(d),
                    _ => false,
                })
            });
            match idx {
                Some(k) => {
                    let (d, s) = remaining.remove(k);
                    let sr = self.reg(s);
                    if sr != d {
                        self.push(MInst::mv(d, sr));
                    }
                    emitted.push(d);
                }
                None => {
                    // Cycle: break it with a temp.
                    let (d, s) = remaining.remove(0);
                    let is_f = self.mf.is_float(d);
                    let tmp = self.mf.new_vreg(is_f);
                    let sr = self.reg(s);
                    self.push(MInst::mv(tmp, sr));
                    // Re-point any remaining source equal to d? Sources are
                    // IR values, not regs; instead emit the final move from
                    // tmp after the rest complete.
                    // Defer: emit remaining pairs that read d first.
                    let mut defer: Vec<(MReg, Val)> = vec![];
                    while let Some(pos) = remaining.iter().position(|(_, s2)| match s2 {
                        Val::Inst(si) => self.vmap.get(si) == Some(&d),
                        Val::Arg(a) => self.arg_regs.get(*a as usize) == Some(&d),
                        _ => false,
                    }) {
                        defer.push(remaining.remove(pos));
                    }
                    for (d2, s2) in defer {
                        let sr2 = self.reg(s2);
                        if sr2 != d2 {
                            self.push(MInst::mv(d2, sr2));
                        }
                    }
                    self.push(MInst::mv(d, tmp));
                }
            }
        }
        let _ = dsts;
        let _ = emitted;
    }

    fn lower(&mut self, id: InstId) {
        let inst = self.f.inst(id);
        let kind = inst.kind.clone();
        let dst = self.vmap.get(&id).copied();
        match kind {
            InstKind::Phi { .. } => {} // handled by predecessor copies
            InstKind::Bin { op, a, b } => self.lower_bin(dst.unwrap(), op, a, b),
            InstKind::Un { op, a } => {
                let d = dst.unwrap();
                let s = self.reg(a);
                let mop = match op {
                    UnOp::Not => {
                        self.push(MInst::rri(Op::XORI, d, s, -1));
                        return;
                    }
                    UnOp::FNeg => Op::FNEG,
                    UnOp::FSqrt => Op::FSQRT,
                    UnOp::FAbs => Op::FABS,
                    UnOp::FExp => Op::FEXP,
                    UnOp::FLog => Op::FLOG,
                    UnOp::FFloor => Op::FFLOOR,
                    UnOp::SiToFp => Op::FCVTSW,
                    UnOp::FpToSi => Op::FCVTWS,
                    UnOp::ZExt => Op::MOV,
                    UnOp::Trunc => {
                        self.push(MInst::rrr(Op::SNE, d, s, MReg::phys(0)));
                        return;
                    }
                    UnOp::FToBits => Op::FMVXW,
                    UnOp::BitsToF => Op::FMVWX,
                };
                self.push(MInst::rrr(mop, d, s, NONE));
            }
            InstKind::ICmp { pred, a, b } => {
                let d = dst.unwrap();
                let (mut x, mut y) = (self.reg(a), self.reg(b));
                let op = match pred {
                    ICmp::Eq => Op::SEQ,
                    ICmp::Ne => Op::SNE,
                    ICmp::Slt => Op::SLT,
                    ICmp::Sle => Op::SLE,
                    ICmp::Sgt => {
                        std::mem::swap(&mut x, &mut y);
                        Op::SLT
                    }
                    ICmp::Sge => {
                        std::mem::swap(&mut x, &mut y);
                        Op::SLE
                    }
                    ICmp::Ult => Op::SLTU,
                    ICmp::Uge => Op::SGEU,
                };
                self.push(MInst::rrr(op, d, x, y));
            }
            InstKind::FCmp { pred, a, b } => {
                let d = dst.unwrap();
                let x = self.reg(a);
                let y = self.reg(b);
                let op = match pred {
                    FCmp::Oeq => Op::FEQ,
                    FCmp::One => Op::FNE,
                    FCmp::Olt => Op::FLT,
                    FCmp::Ole => Op::FLE,
                    FCmp::Ogt => Op::FGT,
                    FCmp::Oge => Op::FGE,
                };
                self.push(MInst::rrr(op, d, x, y));
            }
            InstKind::Select { cond, t, f } => {
                // ZiCond lowering (paper §5.3): mv d, f; vx_cmov d, c, t.
                let d = dst.unwrap();
                let fv = self.reg(f);
                let c = self.reg(cond);
                let tv = self.reg(t);
                self.push(MInst::mv(d, fv));
                self.push(MInst::rrr(Op::CMOV, d, c, tv));
            }
            InstKind::Alloca { .. } => {
                let d = dst.unwrap();
                let off = self.alloca_off[&id];
                self.push(MInst::rri(Op::ADDI, d, MReg::phys(SP), off as i64));
            }
            InstKind::Load { ptr } => {
                let d = dst.unwrap();
                let (b, off) = self.addr(ptr);
                self.push(MInst::rri(Op::LW, d, b, off));
            }
            InstKind::Store { ptr, val } => {
                let v = self.reg(val);
                let (b, off) = self.addr(ptr);
                self.push(MInst {
                    op: Op::SW,
                    rd: NONE,
                    rs1: b,
                    rs2: v,
                    imm: off,
                    ..MInst::new(Op::SW)
                });
            }
            InstKind::Gep {
                base,
                index,
                scale,
                disp,
            } => {
                let d = dst.unwrap();
                let b = self.reg(base);
                match index {
                    Val::I(c, _) => {
                        self.push(MInst::rri(
                            Op::ADDI,
                            d,
                            b,
                            c * scale as i64 + disp as i64,
                        ));
                    }
                    _ => {
                        let i = self.reg(index);
                        let scaled = if scale == 4 {
                            let t = self.mf.new_vreg(false);
                            self.push(MInst::rri(Op::SLLI, t, i, 2));
                            t
                        } else if scale == 1 {
                            i
                        } else {
                            let t = self.mf.new_vreg(false);
                            let c = self.mf.new_vreg(false);
                            self.push(MInst::li(c, scale as i64));
                            self.push(MInst::rrr(Op::MUL, t, i, c));
                            t
                        };
                        if disp == 0 {
                            self.push(MInst::rrr(Op::ADD, d, b, scaled));
                        } else {
                            let t2 = self.mf.new_vreg(false);
                            self.push(MInst::rrr(Op::ADD, t2, b, scaled));
                            self.push(MInst::rri(Op::ADDI, d, t2, disp as i64));
                        }
                    }
                }
            }
            InstKind::Call { callee, args } => {
                self.mf.has_calls = true;
                let mut ni = 0u8;
                let mut nf = 0u8;
                let arg_regs: Vec<MReg> = args.iter().map(|&a| self.reg(a)).collect();
                for (i, &a) in args.iter().enumerate() {
                    let is_f = self.f.val_type(a) == Type::F32;
                    let phys = if is_f {
                        let r = MReg::phys(FA0 + nf);
                        nf += 1;
                        r
                    } else {
                        let r = MReg::phys(A0 + ni);
                        ni += 1;
                        r
                    };
                    assert!(ni <= 8 && nf <= 8, "too many call arguments");
                    self.push(MInst::mv(phys, arg_regs[i]));
                }
                let mut jal = MInst::new(Op::JAL);
                jal.rd = MReg::phys(RA);
                jal.callee = Some(self.m.func(callee).name.clone());
                self.push(jal);
                if let Some(d) = dst {
                    let is_f = self.f.inst(id).ty == Type::F32;
                    let src = if is_f { MReg::phys(FA0) } else { MReg::phys(A0) };
                    self.push(MInst::mv(d, src));
                }
            }
            InstKind::Intr { intr, args } => self.lower_intr(dst, intr, &args),
            InstKind::Br { target } => {
                self.phi_copies(BlockId(self.cur as u32));
                let mut j = MInst::new(Op::J);
                j.t1 = Some(target.idx());
                self.push(j);
            }
            InstKind::CondBr { cond, t, f } => {
                let c = self.reg(cond);
                self.phi_copies(BlockId(self.cur as u32));
                let mut bnez = MInst {
                    rs1: c,
                    ..MInst::new(Op::BNEZ)
                };
                bnez.t1 = Some(t.idx());
                self.push(bnez);
                let mut j = MInst::new(Op::J);
                j.t1 = Some(f.idx());
                self.push(j);
            }
            InstKind::SplitBr {
                cond,
                neg,
                then_b,
                else_b,
                ipdom,
            } => {
                let c = self.reg(cond);
                self.phi_copies(BlockId(self.cur as u32));
                let mut s = MInst {
                    rs1: c,
                    ..MInst::new(if neg { Op::SPLITN } else { Op::SPLIT })
                };
                s.t1 = Some(then_b.idx());
                s.t2 = Some(else_b.idx());
                s.tjoin = Some(ipdom.idx());
                self.push(s);
            }
            InstKind::PredBr {
                cond,
                mask,
                body,
                exit,
            } => {
                let c = self.reg(cond);
                let m = self.reg(mask);
                self.phi_copies(BlockId(self.cur as u32));
                let mut p = MInst {
                    rs1: c,
                    rs2: m,
                    ..MInst::new(Op::PRED)
                };
                p.t1 = Some(body.idx());
                p.t2 = Some(exit.idx());
                self.push(p);
            }
            InstKind::Ret { val } => {
                if let Some(v) = val {
                    let is_f = self.f.val_type(v) == Type::F32;
                    let r = self.reg(v);
                    let phys = if is_f { MReg::phys(FA0) } else { MReg::phys(A0) };
                    self.push(MInst::mv(phys, r));
                }
                // JALR x0, ra, 0 == ret
                let mut ret = MInst::new(Op::JALR);
                ret.rd = MReg::phys(0);
                ret.rs1 = MReg::phys(RA);
                self.push(ret);
            }
            InstKind::Unreachable => {
                let mut e = MInst::new(Op::ECALL);
                e.imm = 1;
                self.push(e);
            }
        }
    }

    fn lower_bin(&mut self, d: MReg, op: BinOp, a: Val, b: Val) {
        // Immediate forms.
        if let Val::I(c, _) = b {
            let imm_op = match op {
                BinOp::Add => Some(Op::ADDI),
                BinOp::Sub => Some(Op::ADDI),
                BinOp::And => Some(Op::ANDI),
                BinOp::Or => Some(Op::ORI),
                BinOp::Xor => Some(Op::XORI),
                BinOp::Shl => Some(Op::SLLI),
                BinOp::LShr => Some(Op::SRLI),
                BinOp::AShr => Some(Op::SRAI),
                _ => None,
            };
            if let Some(io) = imm_op {
                let x = self.reg(a);
                let imm = if op == BinOp::Sub { -c } else { c };
                self.push(MInst::rri(io, d, x, imm));
                return;
            }
        }
        let mop = match op {
            BinOp::Add => Op::ADD,
            BinOp::Sub => Op::SUB,
            BinOp::Mul => Op::MUL,
            BinOp::SDiv => Op::DIV,
            BinOp::SRem => Op::REM,
            BinOp::UDiv => Op::DIVU,
            BinOp::URem => Op::REMU,
            BinOp::And => Op::AND,
            BinOp::Or => Op::OR,
            BinOp::Xor => Op::XOR,
            BinOp::Shl => Op::SLL,
            BinOp::LShr => Op::SRL,
            BinOp::AShr => Op::SRA,
            BinOp::SMin => Op::MIN,
            BinOp::SMax => Op::MAX,
            BinOp::FAdd => Op::FADD,
            BinOp::FSub => Op::FSUB,
            BinOp::FMul => Op::FMUL,
            BinOp::FDiv => Op::FDIV,
            BinOp::FMin => Op::FMIN,
            BinOp::FMax => Op::FMAX,
        };
        let x = self.reg(a);
        let y = self.reg(b);
        self.push(MInst::rrr(mop, d, x, y));
    }

    fn lower_intr(&mut self, dst: Option<MReg>, intr: Intr, args: &[Val]) {
        match intr {
            Intr::Csr(c) => {
                let d = dst.unwrap();
                let id = match c {
                    Csr::LaneId => 0,
                    Csr::WarpId => 1,
                    Csr::CoreId => 2,
                    Csr::NumThreads => 3,
                    Csr::NumWarps => 4,
                    Csr::NumCores => 5,
                };
                self.push(MInst::rri(Op::CSRR, d, NONE, id));
            }
            Intr::Barrier => {
                // args: [id const, count]
                let id = match args.first() {
                    Some(Val::I(v, _)) => *v,
                    _ => 0,
                };
                let cnt = self.reg(args[1]);
                let mut b = MInst::new(Op::BAR);
                b.rs1 = cnt;
                b.imm = id;
                self.push(b);
            }
            Intr::Atomic(op) => {
                let d = dst.unwrap();
                let a = self.reg(args[0]);
                let v = self.reg(args[1]);
                let mop = match op {
                    AtomOp::Add => Op::AMOADD,
                    AtomOp::And => Op::AMOAND,
                    AtomOp::Or => Op::AMOOR,
                    AtomOp::Xor => Op::AMOXOR,
                    AtomOp::Min => Op::AMOMIN,
                    AtomOp::Max => Op::AMOMAX,
                    AtomOp::Exch => Op::AMOSWAP,
                };
                self.push(MInst::rrr(mop, d, a, v));
            }
            Intr::AtomicCas => {
                let d = dst.unwrap();
                let a = self.reg(args[0]);
                let cmp = self.reg(args[1]);
                let nv = self.reg(args[2]);
                self.push(MInst::mv(d, cmp));
                self.push(MInst::rrr(Op::AMOCAS, d, a, nv));
            }
            Intr::VoteAll | Intr::VoteAny | Intr::Ballot => {
                let d = dst.unwrap();
                let p = self.reg(args[0]);
                let op = match intr {
                    Intr::VoteAll => Op::VOTEALL,
                    Intr::VoteAny => Op::VOTEANY,
                    _ => Op::BALLOT,
                };
                self.push(MInst::rrr(op, d, p, NONE));
            }
            Intr::Shfl => {
                let d = dst.unwrap();
                let v = self.reg(args[0]);
                let l = self.reg(args[1]);
                self.push(MInst::rrr(Op::SHFL, d, v, l));
            }
            Intr::Join => self.push(MInst::new(Op::JOIN)),
            Intr::Tmc => {
                let m = self.reg(args[0]);
                let mut t = MInst::new(Op::TMC);
                t.rs1 = m;
                self.push(t);
            }
            Intr::Mask => {
                let d = dst.unwrap();
                self.push(MInst::rrr(Op::MASK, d, NONE, NONE));
            }
            Intr::PrintI | Intr::PrintF => {
                let v = self.reg(args[0]);
                let mut p = MInst::new(if matches!(intr, Intr::PrintI) {
                    Op::PRINTI
                } else {
                    Op::PRINTF
                });
                p.rs1 = v;
                self.push(p);
            }
            Intr::WorkItem(_) => {
                panic!("work-item intrinsic survived to isel — schedule pass missing")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Builder, Param};

    fn gaddrs() -> crate::backend::emit::LayoutInfo {
        Default::default()
    }

    #[test]
    fn selects_arith_kernel() {
        let mut m = Module::new("t");
        let mut f = Function::new(
            "k",
            vec![
                Param {
                    name: "p".into(),
                    ty: Type::Ptr(AddrSpace::Global),
                    uniform: true,
                },
                Param {
                    name: "x".into(),
                    ty: Type::I32,
                    uniform: true,
                },
            ],
            Type::Void,
        );
        {
            let mut b = Builder::new(&mut f);
            let v = b.add(Val::Arg(1), Val::ci(3));
            let g = b.gep(Val::Arg(0), v, 4);
            b.store(g, v);
            b.ret(None);
        }
        let fid = m.add_func(f);
        let mf = select_function(&m, fid, &gaddrs(), &Default::default()).unwrap();
        let ops: Vec<Op> = mf.blocks[0].insts.iter().map(|i| i.op).collect();
        assert!(ops.contains(&Op::ADDI)); // add with immediate
        assert!(ops.contains(&Op::SLLI)); // gep scaling
        assert!(ops.contains(&Op::SW));
        assert!(ops.contains(&Op::JALR)); // ret
    }

    #[test]
    fn phi_copies_on_preds() {
        let mut m = Module::new("t");
        let mut f = Function::new(
            "k",
            vec![Param {
                name: "c".into(),
                ty: Type::I1,
                uniform: false,
            }],
            Type::I32,
        );
        let t = f.add_block("t");
        let e = f.add_block("e");
        let j = f.add_block("j");
        let mut b = Builder::new(&mut f);
        b.cond_br(Val::Arg(0), t, e);
        b.set_block(t);
        b.br(j);
        b.set_block(e);
        b.br(j);
        b.set_block(j);
        let p = b.phi(Type::I32, vec![(t, Val::ci(1)), (e, Val::ci(2))]);
        b.ret(Some(p));
        let fid = m.add_func(f);
        let mf = select_function(&m, fid, &gaddrs(), &Default::default()).unwrap();
        // Both preds of j end with [LI, MOV, J].
        for bi in [t.idx(), e.idx()] {
            let ops: Vec<Op> = mf.blocks[bi].insts.iter().map(|i| i.op).collect();
            assert!(ops.contains(&Op::MOV), "block {bi} ops {ops:?}");
            assert_eq!(*ops.last().unwrap(), Op::J);
        }
    }

    #[test]
    fn split_lowering_carries_targets() {
        let mut m = Module::new("t");
        let mut f = Function::new("k", vec![], Type::Void);
        let t = f.add_block("t");
        let e = f.add_block("e");
        let j = f.add_block("j");
        let mut b = Builder::new(&mut f);
        let lane = b.intr(Intr::Csr(Csr::LaneId), vec![]);
        let c = b.icmp(ICmp::Slt, lane, Val::ci(4));
        b.split_br(c, t, e, j);
        b.set_block(t);
        b.br(j);
        b.set_block(e);
        b.br(j);
        b.set_block(j);
        b.intr(Intr::Join, vec![]);
        b.ret(None);
        let fid = m.add_func(f);
        let mf = select_function(&m, fid, &gaddrs(), &Default::default()).unwrap();
        let split = mf.blocks[0]
            .insts
            .iter()
            .find(|i| i.op == Op::SPLIT)
            .unwrap();
        assert_eq!(split.t1, Some(t.idx()));
        assert_eq!(split.t2, Some(e.idx()));
        assert_eq!(split.tjoin, Some(j.idx()));
        assert!(mf.blocks[j.idx()].insts.iter().any(|i| i.op == Op::JOIN));
    }

    /// Feature refusal: extension ops on a target lacking them are typed
    /// back-end errors naming the gate, never silent selections.
    #[test]
    fn refuses_extension_ops_target_lacks() {
        use crate::backend::emit::BackendOptions;
        let min = BackendOptions {
            target: crate::target::TargetDesc::vortex_min(),
            zicond: false,
            ..Default::default()
        };
        // vx_shfl on vortex-min.
        let mut m = Module::new("t");
        let mut f = Function::new("k", vec![], Type::Void);
        {
            let mut b = Builder::new(&mut f);
            let lane = b.intr(Intr::Csr(Csr::LaneId), vec![]);
            let s = b.intr(Intr::Shfl, vec![lane, Val::ci(0)]);
            let _ = s;
            b.ret(None);
        }
        let fid = m.add_func(f);
        let e = select_function(&m, fid, &gaddrs(), &min).unwrap_err();
        assert!(e.msg.contains("shfl"), "{e}");
        assert!(e.msg.contains("vortex-min"), "{e}");
        // Select → vx_cmov on vortex-min (unlegalized middle-end output).
        let mut m2 = Module::new("t");
        let mut f2 = Function::new("k", vec![], Type::Void);
        {
            let mut b = Builder::new(&mut f2);
            let lane = b.intr(Intr::Csr(Csr::LaneId), vec![]);
            let c = b.icmp(ICmp::Slt, lane, Val::ci(4));
            let s = b.select(c, Val::ci(1), Val::ci(2));
            let _ = s;
            b.ret(None);
        }
        let fid2 = m2.add_func(f2);
        let e2 = select_function(&m2, fid2, &gaddrs(), &min).unwrap_err();
        assert!(e2.msg.contains("zicond"), "{e2}");
        // The same functions select fine for the full vortex target.
        select_function(&m, fid, &gaddrs(), &Default::default()).unwrap();
        select_function(&m2, fid2, &gaddrs(), &Default::default()).unwrap();
    }

    #[test]
    fn critical_edge_splitting_preserves_ipdom() {
        // SplitBr with else == ipdom (critical edge): the stub must go on
        // the else edge while the reconvergence field keeps pointing at j.
        let mut m = Module::new("t");
        let mut f = Function::new("k", vec![], Type::Void);
        let t = f.add_block("t");
        let j = f.add_block("j");
        let mut b = Builder::new(&mut f);
        let lane = b.intr(Intr::Csr(Csr::LaneId), vec![]);
        let c = b.icmp(ICmp::Slt, lane, Val::ci(4));
        b.split_br(c, t, j, j);
        b.set_block(t);
        b.br(j);
        b.set_block(j);
        b.intr(Intr::Join, vec![]);
        b.ret(None);
        let fid = m.add_func(f);
        let mf = select_function(&m, fid, &gaddrs(), &Default::default()).unwrap();
        let split = mf.blocks[0]
            .insts
            .iter()
            .find(|i| i.op == Op::SPLIT)
            .unwrap();
        assert_eq!(split.tjoin, Some(j.idx()));
        assert_ne!(split.t2, Some(j.idx()), "else edge must be split");
    }
}
