//! MIR cleanups: copy propagation, dead-code elimination, and the final
//! block-layout / branch-simplification pass (paper §4.4 "a final
//! machine-code optimization pass then eliminates redundant register-copy
//! instructions").
//!
//! The layout pass may put a split's *else* arm on the fallthrough path,
//! swapping the split's arms — this is exactly the Fig. 5(a) "branch
//! reordering" hazard: the swap is recorded on the instruction but the
//! negate flag is NOT fixed here; the safety net repairs it. (Disabling
//! the safety net demonstrably mis-executes — see the safety-net tests.)

use super::isa::Op;
use super::mir::{MFunction, MReg};
use std::collections::{HashMap, HashSet};

/// Forward-propagate single-def → single-def virtual copies and fold
/// redundant LI chains (same-block re-materializations of one constant,
/// which GVN/strength reduction expose in bulk). Returns copies removed.
///
/// A `fwd` cycle (mutually-referential MOVs) would previously spin the
/// resolver into its guard limit and return a register whose defining MOV
/// had just been deleted — a silent miscompile. Cycles are now detected
/// up front: the whole chain is skipped (its MOVs stay), and a debug
/// assertion fires so the broken input cannot hide.
pub fn copy_prop(f: &mut MFunction) -> usize {
    // Count defs per vreg.
    let mut defs: HashMap<MReg, u32> = HashMap::new();
    for b in &f.blocks {
        for i in &b.insts {
            if let Some(d) = i.def() {
                if d.is_virt() {
                    *defs.entry(d).or_insert(0) += 1;
                }
            }
        }
    }
    // Map: dst -> src for removable MOVs, plus dst -> canonical dst for
    // duplicate same-block LIs.
    let mut fwd: HashMap<MReg, MReg> = HashMap::new();
    for b in &f.blocks {
        let mut li_seen: HashMap<i64, MReg> = HashMap::new();
        for i in &b.insts {
            if i.op == Op::MOV
                && i.rd.is_virt()
                && i.rs1.is_virt()
                && defs.get(&i.rd) == Some(&1)
                && defs.get(&i.rs1) == Some(&1)
            {
                fwd.insert(i.rd, i.rs1);
            }
            if i.op == Op::LI && i.rd.is_virt() && defs.get(&i.rd) == Some(&1) {
                match li_seen.get(&i.imm).copied() {
                    Some(first) if first != i.rd => {
                        fwd.insert(i.rd, first);
                    }
                    Some(_) => {}
                    None => {
                        li_seen.insert(i.imm, i.rd);
                    }
                }
            }
        }
    }
    if fwd.is_empty() {
        return 0;
    }
    // Resolve every chain to its root, detecting cycles. Any chain that
    // reaches a cycle is dropped wholesale (conservative: keep the MOVs).
    let mut resolved: HashMap<MReg, MReg> = HashMap::new();
    let mut cyclic: HashSet<MReg> = HashSet::new();
    for &start in fwd.keys() {
        if resolved.contains_key(&start) || cyclic.contains(&start) {
            continue;
        }
        let mut seen: Vec<MReg> = vec![start];
        let mut seen_set: HashSet<MReg> = seen.iter().copied().collect();
        let mut r = start;
        loop {
            if let Some(&root) = resolved.get(&r) {
                for &s in &seen {
                    resolved.insert(s, root);
                }
                break;
            }
            if cyclic.contains(&r) {
                cyclic.extend(seen.iter().copied());
                break;
            }
            match fwd.get(&r) {
                Some(&n) => {
                    if seen_set.contains(&n) {
                        debug_assert!(
                            false,
                            "copy_prop: MOV/LI forwarding cycle through v{}",
                            n.0
                        );
                        cyclic.extend(seen.iter().copied());
                        break;
                    }
                    seen.push(n);
                    seen_set.insert(n);
                    r = n;
                }
                None => {
                    // `r` itself is the chain root (not a fwd key): it must
                    // NOT enter `resolved`, or its defining LI/MOV would be
                    // deleted by the retain pass below.
                    for &s in &seen {
                        if s != r {
                            resolved.insert(s, r);
                        }
                    }
                    break;
                }
            }
        }
    }
    for r in &cyclic {
        resolved.remove(r);
    }
    let mut removed = 0;
    for b in f.blocks.iter_mut() {
        b.insts.retain(|i| {
            if matches!(i.op, Op::MOV | Op::LI) && i.rd.is_virt() && resolved.contains_key(&i.rd)
            {
                removed += 1;
                false
            } else {
                true
            }
        });
        for i in b.insts.iter_mut() {
            if i.rs1.is_virt() {
                if let Some(&r) = resolved.get(&i.rs1) {
                    i.rs1 = r;
                }
            }
            if i.rs2.is_virt() {
                if let Some(&r) = resolved.get(&i.rs2) {
                    i.rs2 = r;
                }
            }
            // CMOV/AMOCAS read rd, but rd is also written: never forwarded
            // (its def count is >= 2, so it can't be in the map).
        }
    }
    removed
}

/// Remove side-effect-free instructions whose virtual def is never used.
pub fn dce(f: &mut MFunction) -> usize {
    let mut removed = 0;
    loop {
        let mut used: HashMap<MReg, u32> = HashMap::new();
        for b in &f.blocks {
            for i in &b.insts {
                for u in i.uses() {
                    *used.entry(u).or_insert(0) += 1;
                }
            }
        }
        let mut change = 0;
        for b in f.blocks.iter_mut() {
            b.insts.retain(|i| {
                let removable = matches!(
                    i.op.class(),
                    super::isa::OpClass::Alu | super::isa::OpClass::Mul | super::isa::OpClass::Div | super::isa::OpClass::Fpu | super::isa::OpClass::FDiv | super::isa::OpClass::Sfu
                ) && i.op != Op::CMOV
                    && !i.is_terminator()
                    && i.def().map(|d| d.is_virt() && used.get(&d).is_none()).unwrap_or(false);
                if removable {
                    change += 1;
                    false
                } else {
                    true
                }
            });
        }
        removed += change;
        if change == 0 {
            return removed;
        }
    }
}

/// Block layout: order blocks greedily for fallthrough, then simplify
/// branches. Returns the new order (old indices). Rewrites all branch
/// targets in terms of the *new* indices and enforces the ISA's implicit
/// fallthrough rules (SPLIT falls through to its then-arm, PRED to its
/// body).
pub fn layout(f: &mut MFunction) -> Vec<usize> {
    let n = f.blocks.len();
    // Greedy chaining from entry.
    let mut placed = vec![false; n];
    let mut order: Vec<usize> = vec![];
    let mut work: Vec<usize> = vec![0];
    while order.len() < n {
        let cur = match work.pop() {
            Some(c) if !placed[c] => c,
            Some(_) => continue,
            None => match (0..n).find(|&i| !placed[i] && !f.blocks[i].insts.is_empty()) {
                Some(c) => c,
                None => break,
            },
        };
        let mut c = cur;
        loop {
            placed[c] = true;
            order.push(c);
            // Preferred fallthrough successor.
            let last = f.blocks[c].insts.last().cloned();
            let next = match last {
                Some(i) => match i.op {
                    Op::J => i.t1,
                    Op::SPLIT | Op::SPLITN => i.t1, // then-arm falls through
                    Op::PRED => i.t1,               // body falls through
                    _ => None,
                },
                None => None,
            };
            // Queue other successors.
            for s in f.blocks[c].succs() {
                if !placed[s] {
                    work.push(s);
                }
            }
            match next {
                Some(nx) if !placed[nx] => c = nx,
                _ => break,
            }
        }
    }
    // Append any stragglers (unreachable blocks with content).
    for i in 0..n {
        if !placed[i] && !f.blocks[i].insts.is_empty() {
            order.push(i);
            placed[i] = true;
        }
    }
    // Remap blocks.
    let mut new_index = vec![usize::MAX; n];
    for (new_i, &old) in order.iter().enumerate() {
        new_index[old] = new_i;
    }
    let mut new_blocks: Vec<super::mir::MBlock> =
        order.iter().map(|&o| f.blocks[o].clone()).collect();
    for b in new_blocks.iter_mut() {
        for i in b.insts.iter_mut() {
            i.t1 = i.t1.map(|t| new_index[t]);
            i.t2 = i.t2.map(|t| new_index[t]);
            i.tjoin = i.tjoin.map(|t| new_index[t]);
        }
    }
    f.blocks = new_blocks;

    // Branch simplification + fallthrough enforcement.
    let nb = f.blocks.len();
    for bi in 0..nb {
        let next = bi + 1;
        let Some(last) = f.blocks[bi].insts.last().cloned() else {
            continue;
        };
        match last.op {
            Op::J => {
                if last.t1 == Some(next) {
                    f.blocks[bi].insts.pop();
                }
            }
            Op::BNEZ | Op::BEQZ => {}
            Op::SPLIT | Op::SPLITN => {
                let li = f.blocks[bi].insts.len() - 1;
                if last.t1 == Some(next) {
                    // already falls through
                } else if last.t2 == Some(next) {
                    // Swap arms for fallthrough — the Fig. 5(a) hazard:
                    // negation is NOT fixed here.
                    let inst = &mut f.blocks[bi].insts[li];
                    std::mem::swap(&mut inst.t1, &mut inst.t2);
                    inst.swapped = !inst.swapped;
                } else {
                    // Neither arm is next: the emitter inserts an explicit
                    // `j then` after the split (the split itself only
                    // transfers control on the else/empty-then path).
                    let _ = li;
                }
            }
            _ => {}
        }
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::mir::{MBlock, MInst, NONE};

    #[test]
    fn copy_prop_folds_chain() {
        let mut f = MFunction {
            name: "t".into(),
            blocks: vec![MBlock::default()],
            vreg_float: vec![],
            frame_size: 0,
            spill_size: 0,
            has_calls: false,
            local_mem_size: 0,
        };
        let a = f.new_vreg(false);
        let b = f.new_vreg(false);
        let c = f.new_vreg(false);
        f.blocks[0].insts.push(MInst::li(a, 7));
        f.blocks[0].insts.push(MInst::mv(b, a));
        f.blocks[0].insts.push(MInst::mv(c, b));
        f.blocks[0]
            .insts
            .push(MInst::rrr(Op::ADD, MReg::phys(10), c, c));
        let removed = copy_prop(&mut f);
        assert_eq!(removed, 2);
        let add = f.blocks[0].insts.iter().find(|i| i.op == Op::ADD).unwrap();
        assert_eq!(add.rs1, a);
        assert_eq!(add.rs2, a);
    }

    /// A mutually-referential MOV pair (broken input) must not be folded:
    /// in release the chain is skipped wholesale; in debug the assertion
    /// fires so the miscompile cannot hide.
    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "forwarding cycle"))]
    fn copy_prop_skips_mov_cycle() {
        let mut f = MFunction {
            name: "t".into(),
            blocks: vec![MBlock::default()],
            vreg_float: vec![],
            frame_size: 0,
            spill_size: 0,
            has_calls: false,
            local_mem_size: 0,
        };
        let a = f.new_vreg(false);
        let b = f.new_vreg(false);
        f.blocks[0].insts.push(MInst::mv(a, b));
        f.blocks[0].insts.push(MInst::mv(b, a));
        f.blocks[0]
            .insts
            .push(MInst::rrr(Op::ADD, MReg::phys(10), a, b));
        let removed = copy_prop(&mut f);
        assert_eq!(removed, 0, "cyclic chain must be left alone");
        let movs = f.blocks[0].insts.iter().filter(|i| i.op == Op::MOV).count();
        assert_eq!(movs, 2);
        let add = f.blocks[0].insts.iter().find(|i| i.op == Op::ADD).unwrap();
        assert_eq!((add.rs1, add.rs2), (a, b), "uses must not be rewritten");
    }

    /// Duplicate same-block LIs of one constant fold onto the first.
    #[test]
    fn copy_prop_dedups_li_chains() {
        let mut f = MFunction {
            name: "t".into(),
            blocks: vec![MBlock::default()],
            vreg_float: vec![],
            frame_size: 0,
            spill_size: 0,
            has_calls: false,
            local_mem_size: 0,
        };
        let a = f.new_vreg(false);
        let b = f.new_vreg(false);
        let c = f.new_vreg(false);
        f.blocks[0].insts.push(MInst::li(a, 42));
        f.blocks[0].insts.push(MInst::li(b, 42)); // redundant
        f.blocks[0].insts.push(MInst::li(c, 7)); // different constant
        f.blocks[0]
            .insts
            .push(MInst::rrr(Op::ADD, MReg::phys(10), b, c));
        let removed = copy_prop(&mut f);
        assert_eq!(removed, 1);
        let lis: Vec<i64> = f
            .blocks[0]
            .insts
            .iter()
            .filter(|i| i.op == Op::LI)
            .map(|i| i.imm)
            .collect();
        assert_eq!(lis, vec![42, 7]);
        let add = f.blocks[0].insts.iter().find(|i| i.op == Op::ADD).unwrap();
        assert_eq!((add.rs1, add.rs2), (a, c));
    }

    #[test]
    fn dce_removes_dead_li() {
        let mut f = MFunction {
            name: "t".into(),
            blocks: vec![MBlock::default()],
            vreg_float: vec![],
            frame_size: 0,
            spill_size: 0,
            has_calls: false,
            local_mem_size: 0,
        };
        let a = f.new_vreg(false);
        let b = f.new_vreg(false);
        f.blocks[0].insts.push(MInst::li(a, 7));
        f.blocks[0].insts.push(MInst::li(b, 9));
        f.blocks[0]
            .insts
            .push(MInst::rrr(Op::ADD, MReg::phys(10), a, a));
        assert_eq!(dce(&mut f), 1);
        assert!(!f.blocks[0].insts.iter().any(|i| i.rd == b));
    }

    #[test]
    fn layout_orders_fallthrough_and_marks_swaps() {
        // b0: split then=b2 else=b1 join=b3 ; b1: j b3 ; b2: j b3 ; b3: ret
        let mut f = MFunction {
            name: "t".into(),
            blocks: vec![
                MBlock::default(),
                MBlock::default(),
                MBlock::default(),
                MBlock::default(),
            ],
            vreg_float: vec![],
            frame_size: 0,
            spill_size: 0,
            has_calls: false,
            local_mem_size: 0,
        };
        let mut s = MInst::new(Op::SPLIT);
        s.rs1 = MReg::phys(5);
        s.t1 = Some(2);
        s.t2 = Some(1);
        s.tjoin = Some(3);
        f.blocks[0].insts.push(s);
        let mut j1 = MInst::new(Op::J);
        j1.t1 = Some(3);
        f.blocks[1].insts.push(j1.clone());
        f.blocks[2].insts.push(j1.clone());
        f.blocks[3].insts.push(MInst {
            rd: MReg::phys(0),
            rs1: MReg::phys(1),
            rs2: NONE,
            ..MInst::new(Op::JALR)
        });
        let order = layout(&mut f);
        // Entry first; then-arm (old b2) should follow the split.
        assert_eq!(order[0], 0);
        assert_eq!(order[1], 2);
        let split = &f.blocks[0].insts[0];
        assert_eq!(split.t1, Some(1)); // new index of old b2
        assert!(!split.swapped);
    }
}
