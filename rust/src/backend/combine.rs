//! MIR combine/peephole pass — the backend half of the codegen-quality
//! rung (runs between isel cleanups and register allocation, only when
//! `BackendOptions::codegen_opt` is set).
//!
//! On the blocking-issue Vortex timing model every eliminated dynamic
//! instruction is a direct cycle win, so the pass goes after the dynamic
//! instruction count the naive selector leaves behind:
//!
//! * **absolute-address folding through `x0`** — `li v, addr` feeding a
//!   global `lw`/`sw` base folds into the memory immediate
//!   (`lw d, addr(x0)`), killing the `li`. Refused when the combined
//!   displacement does not fit the i32 immediate (the emitter truncates
//!   `MInst::imm` to i32, so an out-of-range fold would be a silent
//!   miscompile).
//! * **`addi`-chain collapsing** — `addi t, b, k` feeding a load/store
//!   (or another `addi`) folds `k` into the consumer's immediate. Bases
//!   may be single-def vregs, `x0`, or `sp` (constant inside the body:
//!   the prologue/epilogue are inserted *after* this pass).
//! * **compare-before-branch fusion** — `sne t, a, x0; bnez t` becomes
//!   `bnez a` (and the `seq` variants flip the branch sense). Sound
//!   because `beqz`/`bnez` only exist for statically-uniform conditions
//!   and the uniformity analysis only proves `t` uniform when `a` is.
//! * **identity-op elimination** — `addi d, s, 0`, shift-by-0, `ori`/
//!   `xori` 0 and `andi -1` become copies for `mir_opt::copy_prop` to
//!   fold; a post-regalloc [`cleanup_identities`] removes the `mv r, r`
//!   residue that copy coalescing exposes.
//! * **cross-block `li` rematerialization dedup** — generalizes the
//!   block-local dedup in `mir_opt::copy_prop` across the dominator
//!   tree. This is the one pattern that *extends* a live range across
//!   blocks, so it refuses any candidate pair with a mask-widening
//!   operation (`vx_tmc`, `vx_pred`, `vx_join`) on a connecting path: a
//!   lane activated between the two `li`s would read a register it never
//!   wrote. Folds at a *use site* need no such check — they recompute
//!   the same per-lane value from registers the lane demonstrably wrote
//!   (single-def SSA residue), never resurrect a stale one.
//!
//! All rewrites require the forwarded-through vregs to be single-def
//! (the SSA residue isel leaves; phi destinations are multi-def and are
//! never touched).

use super::isa::Op;
use super::mir::{MFunction, MReg, NONE};
use crate::analysis::graphdom;
use std::collections::HashMap;

/// What the pass did (per function).
#[derive(Debug, Default, Clone, Copy)]
pub struct CombineReport {
    /// `li` bases folded into absolute `lw addr(x0)` / `sw addr(x0)`.
    pub addr_folds: usize,
    /// `addi` displacements collapsed into consumer immediates.
    pub addi_folds: usize,
    /// Compare-before-branch pairs fused.
    pub branch_fusions: usize,
    /// Identity ops rewritten to copies (pre-RA) or removed (post-RA).
    pub identities: usize,
    /// Cross-block duplicate `li`s forwarded to a dominating twin.
    pub li_dedups: usize,
}

impl CombineReport {
    pub fn total(&self) -> usize {
        self.addr_folds + self.addi_folds + self.branch_fusions + self.identities + self.li_dedups
    }
}

/// The defining instruction of a single-def vreg (the fields the
/// patterns need).
#[derive(Clone, Copy)]
struct DefSite {
    op: Op,
    rs1: MReg,
    rs2: MReg,
    imm: i64,
}

/// Single-def tracking, owned (no borrow of the function retained) so
/// rewriting can proceed while consulting it.
struct Defs {
    count: Vec<u32>,
    site: Vec<Option<DefSite>>,
    float: Vec<bool>,
}

impl Defs {
    fn build(f: &MFunction) -> Defs {
        let nv = f.vreg_float.len();
        let mut d = Defs {
            count: vec![0; nv],
            site: vec![None; nv],
            float: f.vreg_float.clone(),
        };
        for b in &f.blocks {
            for i in &b.insts {
                if let Some(r) = i.def() {
                    if r.is_virt() {
                        let v = r.virt_idx();
                        d.count[v] += 1;
                        d.site[v] = Some(DefSite {
                            op: i.op,
                            rs1: i.rs1,
                            rs2: i.rs2,
                            imm: i.imm,
                        });
                    }
                }
            }
        }
        d
    }

    fn single(&self, r: MReg) -> Option<DefSite> {
        if r.is_virt() && self.count[r.virt_idx()] == 1 {
            self.site[r.virt_idx()]
        } else {
            None
        }
    }

    fn single_int(&self, r: MReg) -> Option<DefSite> {
        match self.single(r) {
            Some(s) if !self.float[r.virt_idx()] => Some(s),
            _ => None,
        }
    }

    /// A register whose value is constant between a folded-away def and
    /// its use: `x0`, `sp` (the prologue/epilogue are inserted after
    /// this pass, so `sp` is invariant inside the body), or a
    /// single-def integer vreg.
    fn stable_base(&self, r: MReg) -> bool {
        if r == MReg::phys(0) || r == MReg::phys(super::isa::SP) {
            return true;
        }
        r.is_virt() && !self.float[r.virt_idx()] && self.count[r.virt_idx()] == 1
    }
}

fn fits_i32(v: i64) -> bool {
    i32::try_from(v).is_ok()
}

/// The one identity-op table shared by the pre-RA copy conversion and
/// the post-RA cleanup (keeping the two passes from drifting apart).
fn identity_imm(op: Op, imm: i64) -> bool {
    match op {
        Op::ADDI | Op::ORI | Op::XORI | Op::SLLI | Op::SRLI | Op::SRAI => imm == 0,
        Op::ANDI => imm == -1,
        _ => false,
    }
}

/// Run the pre-regalloc combine patterns. Call `mir_opt::copy_prop` +
/// `mir_opt::dce` afterwards to fold the copies this exposes and drop
/// the dead `li`/compare defs.
pub fn run(f: &mut MFunction) -> CombineReport {
    let mut rep = CombineReport::default();
    // A couple of rounds: folding an addi link exposes the li behind it.
    for _ in 0..3 {
        let before = rep.total();
        fold_identities(f, &mut rep);
        fold_uses(f, &mut rep);
        if rep.total() == before {
            break;
        }
    }
    dedup_li(f, &mut rep);
    rep
}

/// Identity ops become plain copies (folded by `copy_prop`).
fn fold_identities(f: &mut MFunction, rep: &mut CombineReport) {
    for b in f.blocks.iter_mut() {
        for i in b.insts.iter_mut() {
            if identity_imm(i.op, i.imm) && !i.rd.is_none() && !i.rs1.is_none() {
                i.op = Op::MOV;
                i.imm = 0;
                rep.identities += 1;
            }
        }
    }
}

/// At-use folds: address materialization into memory immediates, addi
/// chains, and compare-before-branch fusion. Per-lane safe without any
/// path analysis: the rewritten use recomputes the value from registers
/// the executing lane wrote itself (single-def bases).
fn fold_uses(f: &mut MFunction, rep: &mut CombineReport) {
    let defs = Defs::build(f);
    for b in f.blocks.iter_mut() {
        for i in b.insts.iter_mut() {
            match i.op {
                Op::LW | Op::SW => {
                    // Chase the base through addi links, then an li root.
                    let mut fuel = 4;
                    while fuel > 0 {
                        fuel -= 1;
                        match defs.single_int(i.rs1) {
                            Some(DefSite { op: Op::LI, imm: c, .. }) => {
                                let total = c + i.imm;
                                if (0..=i32::MAX as i64).contains(&total) {
                                    i.rs1 = MReg::phys(0);
                                    i.imm = total;
                                    rep.addr_folds += 1;
                                }
                                break;
                            }
                            Some(DefSite {
                                op: Op::ADDI,
                                rs1: base,
                                imm: k,
                                ..
                            }) if defs.stable_base(base) && fits_i32(i.imm + k) => {
                                i.rs1 = base;
                                i.imm += k;
                                rep.addi_folds += 1;
                            }
                            _ => break,
                        }
                    }
                }
                Op::ADDI => match defs.single_int(i.rs1) {
                    Some(DefSite {
                        op: Op::ADDI,
                        rs1: base,
                        imm: k,
                        ..
                    }) if defs.stable_base(base) && fits_i32(i.imm + k) => {
                        i.rs1 = base;
                        i.imm += k;
                        rep.addi_folds += 1;
                    }
                    Some(DefSite { op: Op::LI, imm: c, .. }) if fits_i32(c + i.imm) => {
                        // addi over a constant is just another constant.
                        i.op = Op::LI;
                        i.imm += c;
                        i.rs1 = NONE;
                        rep.addi_folds += 1;
                    }
                    _ => {}
                },
                Op::BEQZ | Op::BNEZ => {
                    // sne t, a, 0 ; bnez t  ->  bnez a  (seq flips sense).
                    // The zero may be literal x0 (trunc lowering) or a
                    // materialized `li 0` vreg (icmp-against-constant).
                    if let Some(cmp) = defs.single_int(i.rs1) {
                        let a = cmp.rs1;
                        let value_stable = a == MReg::phys(0) || defs.single_int(a).is_some();
                        let rs2_zero = cmp.rs2 == MReg::phys(0)
                            || matches!(
                                defs.single_int(cmp.rs2),
                                Some(DefSite { op: Op::LI, imm: 0, .. })
                            );
                        if value_stable && rs2_zero {
                            match cmp.op {
                                Op::SNE => {
                                    i.rs1 = a;
                                    rep.branch_fusions += 1;
                                }
                                Op::SEQ => {
                                    i.op = if i.op == Op::BNEZ { Op::BEQZ } else { Op::BNEZ };
                                    i.rs1 = a;
                                    rep.branch_fusions += 1;
                                }
                                _ => {}
                            }
                        }
                    }
                }
                _ => {}
            }
        }
    }
}

/// Mask-widening ops: a lane can become active *after* skipping code
/// containing them, so a live range must never be stretched across one.
fn widens_mask(op: Op) -> bool {
    matches!(op, Op::TMC | Op::PRED | Op::JOIN)
}

/// Cross-block `li` dedup over the dominator tree, refusing any pair
/// with a mask-widening block on a connecting path.
fn dedup_li(f: &mut MFunction, rep: &mut CombineReport) {
    let nb = f.blocks.len();
    if nb == 0 {
        return;
    }
    let defs = Defs::build(f);
    // Single-def li vregs: (vreg idx, imm, float, block). Collected
    // before the dominator/reachability work so functions with no
    // duplicate constants (the common case) pay nothing.
    let mut lis: Vec<(usize, i64, bool, usize)> = vec![];
    for (bi, b) in f.blocks.iter().enumerate() {
        for i in &b.insts {
            if i.op == Op::LI && i.rd.is_virt() && defs.count[i.rd.virt_idx()] == 1 {
                lis.push((i.rd.virt_idx(), i.imm, f.vreg_float[i.rd.virt_idx()], bi));
            }
        }
    }
    let mut keys: Vec<(i64, bool)> = lis.iter().map(|&(_, imm, fl, _)| (imm, fl)).collect();
    keys.sort_unstable();
    if !keys.windows(2).any(|w| w[0] == w[1]) {
        return; // no duplicate (imm, class) anywhere
    }
    let (idom, depth) = graphdom::dominators(nb, 0, |b| f.blocks[b].succs());
    let reach = reachability(f);
    let widening: Vec<bool> = f
        .blocks
        .iter()
        .map(|b| b.insts.iter().any(|i| widens_mask(i.op)))
        .collect();
    let dominates = |a: usize, b: usize| graphdom::strictly_dominates(&idom, a, b);
    // No widening block W may sit on any D -> U path (conservatively:
    // W reachable from D and U reachable from W; D and U themselves
    // count, so a widening op before the def or after the use also
    // refuses — safe over-approximation).
    let path_clear = |d: usize, u: usize| -> bool {
        (0..nb).all(|w| !(widening[w] && reach[d][w] && reach[w][u]))
    };
    // Sort by dominator depth so every strict dominator of an entry is
    // processed — and its root/forwarded status final — before it.
    lis.sort_by_key(|&(v, _, _, bi)| (depth[bi], bi, v));
    let mut fwd: HashMap<usize, usize> = HashMap::new();
    let mut processed: Vec<(usize, i64, bool, usize)> = Vec::with_capacity(lis.len());
    for &(v, imm, fl, bv) in &lis {
        // Only link to designated roots: dominators were processed
        // first (depth order), so a forwarded candidate already has a
        // root and is skipped (keeps the map one level deep, no chains).
        for &(w, imm2, fl2, bw) in &processed {
            if imm == imm2
                && fl == fl2
                && !fwd.contains_key(&w)
                && dominates(bw, bv)
                && path_clear(bw, bv)
            {
                fwd.insert(v, w);
                break;
            }
        }
        processed.push((v, imm, fl, bv));
    }
    if fwd.is_empty() {
        return;
    }
    for b in f.blocks.iter_mut() {
        b.insts.retain(|i| {
            if i.op == Op::LI && i.rd.is_virt() && fwd.contains_key(&i.rd.virt_idx()) {
                rep.li_dedups += 1;
                false
            } else {
                true
            }
        });
        for i in b.insts.iter_mut() {
            if i.rs1.is_virt() {
                if let Some(&r) = fwd.get(&i.rs1.virt_idx()) {
                    i.rs1 = MReg(64 + r as u32);
                }
            }
            if i.rs2.is_virt() {
                if let Some(&r) = fwd.get(&i.rs2.virt_idx()) {
                    i.rs2 = MReg(64 + r as u32);
                }
            }
            // rd of CMOV/AMOCAS is a read too, but those vregs are
            // multi-def (mv + the op) and can never be in `fwd`.
        }
    }
}

/// Block-level reachability closure (`reach[a][b]`: b reachable from a,
/// including a itself).
fn reachability(f: &MFunction) -> Vec<Vec<bool>> {
    let nb = f.blocks.len();
    let succs: Vec<Vec<usize>> = f.blocks.iter().map(|b| b.succs()).collect();
    let mut reach = vec![vec![false; nb]; nb];
    for (start, row) in reach.iter_mut().enumerate() {
        let mut stack = vec![start];
        row[start] = true;
        while let Some(b) = stack.pop() {
            for &s in &succs[b] {
                if s < nb && !row[s] {
                    row[s] = true;
                    stack.push(s);
                }
            }
        }
    }
    reach
}

/// Post-regalloc cleanup: remove the identity residue copy coalescing
/// and the pre-RA folds leave behind (`mv r, r`, `addi r, r, 0`, …).
pub fn cleanup_identities(f: &mut MFunction) -> usize {
    let mut removed = 0;
    for b in f.blocks.iter_mut() {
        b.insts.retain(|i| {
            let same = i.rd == i.rs1 && !i.rd.is_none();
            let identity = same && (i.op == Op::MOV || identity_imm(i.op, i.imm));
            if identity {
                removed += 1;
                false
            } else {
                true
            }
        });
    }
    removed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::mir::{MBlock, MInst};

    fn func(nblocks: usize) -> MFunction {
        MFunction {
            name: "t".into(),
            blocks: (0..nblocks).map(|_| MBlock::default()).collect(),
            vreg_float: vec![],
            frame_size: 0,
            spill_size: 0,
            has_calls: false,
            local_mem_size: 0,
        }
    }

    fn jmp(t: usize) -> MInst {
        let mut j = MInst::new(Op::J);
        j.t1 = Some(t);
        j
    }

    #[test]
    fn folds_li_base_into_absolute_lw() {
        let mut f = func(1);
        let a = f.new_vreg(false);
        let d = f.new_vreg(false);
        f.blocks[0].insts.push(MInst::li(a, 0x1_0000));
        f.blocks[0].insts.push(MInst::rri(Op::LW, d, a, 8));
        let rep = run(&mut f);
        assert_eq!(rep.addr_folds, 1);
        let lw = f.blocks[0].insts.iter().find(|i| i.op == Op::LW).unwrap();
        assert_eq!(lw.rs1, MReg::phys(0));
        assert_eq!(lw.imm, 0x1_0000 + 8);
    }

    /// Negative case: the combined displacement must fit the i32
    /// immediate the emitter encodes — an address beyond it stays
    /// register-based.
    #[test]
    fn refuses_absolute_fold_beyond_i32() {
        let mut f = func(1);
        let a = f.new_vreg(false);
        let d = f.new_vreg(false);
        f.blocks[0].insts.push(MInst::li(a, i32::MAX as i64));
        f.blocks[0].insts.push(MInst::rri(Op::LW, d, a, 8)); // overflows i32
        let rep = run(&mut f);
        assert_eq!(rep.addr_folds, 0);
        let lw = f.blocks[0].insts.iter().find(|i| i.op == Op::LW).unwrap();
        assert_eq!(lw.rs1, a, "oversized absolute address must not fold");
        assert_eq!(lw.imm, 8);
    }

    #[test]
    fn collapses_addi_chain_into_store_imm() {
        let mut f = func(1);
        let base = f.new_vreg(false); // e.g. a pointer argument
        let t1 = f.new_vreg(false);
        let t2 = f.new_vreg(false);
        let v = f.new_vreg(false);
        f.blocks[0].insts.push(MInst::mv(base, MReg::phys(10)));
        f.blocks[0].insts.push(MInst::rri(Op::ADDI, t1, base, 16));
        f.blocks[0].insts.push(MInst::rri(Op::ADDI, t2, t1, 4));
        let mut sw = MInst::new(Op::SW);
        sw.rd = NONE;
        sw.rs1 = t2;
        sw.rs2 = v;
        sw.imm = 8;
        f.blocks[0].insts.push(sw);
        let rep = run(&mut f);
        assert!(rep.addi_folds >= 2, "{rep:?}");
        let sw = f.blocks[0].insts.iter().find(|i| i.op == Op::SW).unwrap();
        assert_eq!(sw.rs1, base);
        assert_eq!(sw.imm, 28);
    }

    #[test]
    fn fuses_compare_before_branch() {
        // sne t, a, x0 ; bnez t  ->  bnez a
        let mut f = func(2);
        let a = f.new_vreg(false);
        let t = f.new_vreg(false);
        f.blocks[0].insts.push(MInst::li(a, 1));
        f.blocks[0]
            .insts
            .push(MInst::rrr(Op::SNE, t, a, MReg::phys(0)));
        let mut bnez = MInst {
            rs1: t,
            ..MInst::new(Op::BNEZ)
        };
        bnez.t1 = Some(1);
        f.blocks[0].insts.push(bnez);
        f.blocks[0].insts.push(jmp(1));
        let rep = run(&mut f);
        assert_eq!(rep.branch_fusions, 1);
        let br = f.blocks[0].insts.iter().find(|i| i.op == Op::BNEZ).unwrap();
        assert_eq!(br.rs1, a);

        // seq flips the sense.
        let mut f2 = func(2);
        let a2 = f2.new_vreg(false);
        let t2 = f2.new_vreg(false);
        f2.blocks[0].insts.push(MInst::li(a2, 1));
        f2.blocks[0]
            .insts
            .push(MInst::rrr(Op::SEQ, t2, a2, MReg::phys(0)));
        let mut beqz = MInst {
            rs1: t2,
            ..MInst::new(Op::BEQZ)
        };
        beqz.t1 = Some(1);
        f2.blocks[0].insts.push(beqz);
        f2.blocks[0].insts.push(jmp(1));
        let rep2 = run(&mut f2);
        assert_eq!(rep2.branch_fusions, 1);
        let br2 = f2.blocks[0]
            .insts
            .iter()
            .find(|i| matches!(i.op, Op::BNEZ | Op::BEQZ))
            .unwrap();
        assert_eq!(br2.op, Op::BNEZ, "seq+beqz must flip to bnez");
        assert_eq!(br2.rs1, a2);
    }

    #[test]
    fn identity_ops_become_copies() {
        let mut f = func(1);
        let a = f.new_vreg(false);
        let b = f.new_vreg(false);
        f.blocks[0].insts.push(MInst::li(a, 5));
        f.blocks[0].insts.push(MInst::rri(Op::ADDI, b, a, 0));
        let rep = run(&mut f);
        assert_eq!(rep.identities, 1);
        assert!(f.blocks[0].insts.iter().any(|i| i.op == Op::MOV));
    }

    #[test]
    fn cross_block_li_dedup_and_widening_refusal() {
        // b0: li v0, 7 ; j b1   b1: [tmc] j b2   b2: li v1, 7 ; add a0, v0, v1
        let build = |widen: bool| -> MFunction {
            let mut f = func(3);
            let v0 = f.new_vreg(false);
            let v1 = f.new_vreg(false);
            f.blocks[0].insts.push(MInst::li(v0, 7));
            f.blocks[0].insts.push(jmp(1));
            if widen {
                let mut t = MInst::new(Op::TMC);
                t.rs1 = MReg::phys(5);
                f.blocks[1].insts.push(t);
            }
            f.blocks[1].insts.push(jmp(2));
            f.blocks[2].insts.push(MInst::li(v1, 7));
            f.blocks[2]
                .insts
                .push(MInst::rrr(Op::ADD, MReg::phys(10), v0, v1));
            f
        };
        let mut f = build(false);
        let rep = run(&mut f);
        assert_eq!(rep.li_dedups, 1);
        let lis = f
            .blocks
            .iter()
            .flat_map(|b| &b.insts)
            .filter(|i| i.op == Op::LI)
            .count();
        assert_eq!(lis, 1);
        let add = f.blocks[2].insts.iter().find(|i| i.op == Op::ADD).unwrap();
        assert_eq!(add.rs1, add.rs2, "both operands forwarded to the root li");

        // With a mask-widening vx_tmc on the path the dedup must refuse.
        let mut fw = build(true);
        let repw = run(&mut fw);
        assert_eq!(repw.li_dedups, 0, "widening path must block li dedup");
        let lis = fw
            .blocks
            .iter()
            .flat_map(|b| &b.insts)
            .filter(|i| i.op == Op::LI)
            .count();
        assert_eq!(lis, 2);
    }

    #[test]
    fn post_ra_cleanup_removes_identity_moves() {
        let mut f = func(1);
        f.blocks[0]
            .insts
            .push(MInst::mv(MReg::phys(7), MReg::phys(7)));
        f.blocks[0]
            .insts
            .push(MInst::rri(Op::ADDI, MReg::phys(8), MReg::phys(8), 0));
        f.blocks[0]
            .insts
            .push(MInst::mv(MReg::phys(7), MReg::phys(8)));
        assert_eq!(cleanup_identities(&mut f), 2);
        assert_eq!(f.blocks[0].insts.len(), 1);
    }
}
