//! The VOLT back-end (paper §4.4): Vortex ISA table, instruction
//! selection, linear-scan register allocation, machine-IR cleanups, the
//! Fig. 5 divergence safety net, and final encoding/linking.

pub mod combine;
pub mod emit;
pub mod isa;
pub mod isel;
pub mod mir;
pub mod mir_opt;
pub mod regalloc;
pub mod safety_net;

pub use emit::{build_image, build_image_threaded, BackendError, BackendOptions, ProgramImage};
