//! Final code generation: memory layout, crt0 synthesis, label resolution
//! and instruction encoding into a [`ProgramImage`] the simulator loads.
//!
//! PCs are instruction indices. crt0 (per Vortex's startup contract,
//! §2.4): each core's warp 0 starts with one active lane, spawns the
//! remaining warps (`vx_wspawn`), then every warp activates all lanes
//! (`vx_tmc`), computes its per-thread stack pointer and calls the kernel
//! dispatcher; on return the warp parks itself with `vx_tmc x0`.

use super::isa::{disasm, MachInst, Op};
use super::mir::{MFunction, MReg, NONE};
use super::{combine, isel, mir_opt, regalloc, safety_net};
use crate::ir::{AddrSpace, FuncId, GlobalId, Loc, Module};
use crate::target::{AddressMap, TargetDesc};
use std::collections::HashMap;

/// Typed back-end failure: which function (if known) and what went wrong.
/// Wrapped into [`crate::driver::VoltError::Backend`] by the driver.
#[derive(Clone, Debug)]
pub struct BackendError {
    /// Function being lowered/linked when the error was detected.
    pub function: Option<String>,
    pub msg: String,
}

impl BackendError {
    pub(crate) fn new(function: Option<&str>, msg: impl Into<String>) -> BackendError {
        BackendError {
            function: function.map(|s| s.to_string()),
            msg: msg.into(),
        }
    }
}

impl std::fmt::Display for BackendError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.function {
            Some(name) => write!(f, "backend error in '{name}': {}", self.msg),
            None => write!(f, "backend error: {}", self.msg),
        }
    }
}

impl std::error::Error for BackendError {}

/// Legacy string-error contexts (`Result<_, String>` + `?`) keep working.
impl From<BackendError> for String {
    fn from(e: BackendError) -> String {
        e.to_string()
    }
}

/// The Vortex memory map (see DESIGN.md), *derived* from
/// [`crate::target::AddressMap::vortex`] so there is exactly one copy of
/// the map. The named constants exist for raw-image tests and host-side
/// helpers; the emitter and simulator read the map from the active
/// [`TargetDesc`] / [`ProgramImage`], so a target with a different map
/// needs no code change.
pub const DATA_BASE: u32 = AddressMap::vortex().data_base;
pub const LOCAL_BASE: u32 = AddressMap::vortex().local_base;
pub const STACK_BASE: u32 = AddressMap::vortex().stack_base;
pub const STACK_SIZE: u32 = AddressMap::vortex().stack_size;
pub const HEAP_BASE: u32 = AddressMap::vortex().heap_base;

#[derive(Clone, Debug)]
pub struct ProgramImage {
    /// Decoded instruction stream (index == PC).
    pub code: Vec<MachInst>,
    /// Encoded form (round-trips with `code`).
    pub words: Vec<u64>,
    /// Initialized data segments (address, bytes).
    pub data: Vec<(u32, Vec<u8>)>,
    /// First free address after static data.
    pub data_end: u32,
    /// Global symbol table (name → address) — drives
    /// `memcpy_to_symbol` (Case Study 2).
    pub global_addr: HashMap<String, u32>,
    /// Symbol extents (name → size in bytes) — bounds-checks symbol
    /// writes.
    pub global_size: HashMap<String, u32>,
    /// Address of the kernel argument block.
    pub args_addr: u32,
    /// Per-core local memory statically used.
    pub local_mem_size: u32,
    /// Kernel (dispatcher) this image was linked for.
    pub kernel: String,
    /// Function entry points (diagnostics).
    pub func_entries: HashMap<String, u32>,
    /// Per-PC source locations (index == PC, parallel to `code`). Inside
    /// each compiled function, PCs with no direct location inherit the
    /// nearest located neighbour (standard line-table fill); crt0 PCs
    /// (< `crt0_len`) are runtime startup code and carry `None`.
    pub pc_loc: Vec<Option<Loc>>,
    /// Length of the crt0 stub at the head of `code` — the boundary the
    /// profiler uses to separate runtime startup from compiled kernels.
    pub crt0_len: u32,
    /// Per-PC spill marker (parallel to `code`): true for the reload
    /// `lw`/store `sw` instructions the register allocator inserted.
    /// The profiler aggregates these into
    /// [`crate::prof::KernelProfile::spill_cycles`] and the cycle bench
    /// publishes the static count per kernel.
    pub pc_spill: Vec<bool>,
    /// Name of the target this image was linked for (stamped into
    /// profiles, traces, and sweep artifacts).
    pub target: String,
    /// The address map the image was laid out against; the simulator
    /// decodes address spaces from this, so image and device can never
    /// disagree about where local/stack/heap memory sits.
    pub addr_map: AddressMap,
}

impl ProgramImage {
    /// Validate a `memcpy_to_symbol`-style write against the symbol table
    /// and the symbol's extent. Returns the error message, or `None` when
    /// the write is acceptable. Shared by the stream (enqueue-time) and
    /// device (run-time) checks so the two can not diverge.
    pub fn symbol_write_error(&self, symbol: &str, offset: u32, len: usize) -> Option<String> {
        if !self.global_addr.contains_key(symbol) {
            return Some(format!("unknown device symbol '{symbol}'"));
        }
        if let Some(&size) = self.global_size.get(symbol) {
            let end = offset as u64 + len as u64;
            if end > size as u64 {
                return Some(format!(
                    "symbol write out of range: '{symbol}' is {size} bytes, write covers \
                     {offset}..{end}"
                ));
            }
        }
        None
    }

    /// Number of spill-traffic instructions linked into the image (the
    /// static spill count reported per kernel by `benches/o3_cycles.rs`).
    pub fn spill_insts(&self) -> usize {
        self.pc_spill.iter().filter(|&&s| s).count()
    }

    pub fn disassemble(&self) -> String {
        let mut s = String::new();
        let mut entries: Vec<(&String, &u32)> = self.func_entries.iter().collect();
        entries.sort_by_key(|(_, &pc)| pc);
        for (idx, inst) in self.code.iter().enumerate() {
            if let Some((name, _)) = entries.iter().find(|(_, &pc)| pc == idx as u32) {
                s.push_str(&format!("\n{name}:\n"));
            }
            let spill = if self.pc_spill.get(idx).copied().unwrap_or(false) {
                "*"
            } else {
                " "
            };
            s.push_str(&format!("{idx:5}:{spill} {}\n", disasm(inst)));
        }
        s
    }
}

/// How CUDA/OpenCL shared (`local`) memory is mapped (paper §5.4 /
/// Fig. 10): onto the per-core scratchpad, or emulated in global memory
/// (the CuPBoP-style fallback) with one bank per core.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SharedMemMapping {
    Local,
    Global,
}

#[derive(Clone, Copy, Debug)]
pub struct BackendOptions {
    pub zicond: bool,
    /// Run the fallthrough layout pass (and its arm-swapping).
    pub opt_layout: bool,
    /// Run the MIR safety net (disable only to demonstrate Fig. 5).
    pub safety_net: bool,
    pub smem: SharedMemMapping,
    /// The backend codegen-quality rung: the MIR combine/peephole pass
    /// plus the allocator quality features (holes, copy coalescing,
    /// Belady spill choice). The raw-struct default is **on** (direct
    /// backend users get the best codegen, and every backend unit test
    /// exercises the rung); the driver instead derives it from the
    /// ladder — on at `OptLevel::O3` and above, off below — so the
    /// `benches/o3_cycles.rs` Recon baseline measures the rung's
    /// harvest.
    pub codegen_opt: bool,
    /// The machine being compiled for: feature gates (isel refusal + the
    /// final image audit), register-file shape for the allocator, and the
    /// address map for layout/crt0.
    pub target: TargetDesc,
}

impl Default for BackendOptions {
    fn default() -> Self {
        BackendOptions {
            zicond: true,
            opt_layout: true,
            safety_net: true,
            smem: SharedMemMapping::Local,
            codegen_opt: true,
            target: TargetDesc::vortex(),
        }
    }
}

/// Maximum cores a global-memory shared-mem bank set supports.
pub const SMEM_MAX_CORES: u32 = 16;

/// Global layout result handed to instruction selection.
#[derive(Clone, Debug, Default)]
pub struct LayoutInfo {
    pub addr: HashMap<GlobalId, u32>,
    /// Local-space globals that live in global memory with one bank per
    /// core: address = base + core_id * stride.
    pub core_banked: std::collections::HashSet<GlobalId>,
    pub bank_stride: u32,
}

/// Lay out module globals against the target's address map: Const/Global
/// into the data segment, Local into the per-core local segment (or,
/// under `SharedMemMapping::Global`, into per-core banks in the data
/// segment).
pub fn layout_globals(
    m: &Module,
    smem: SharedMemMapping,
    map: &AddressMap,
) -> (LayoutInfo, Vec<(u32, Vec<u8>)>, u32, u32) {
    let mut info = LayoutInfo::default();
    let mut data = vec![];
    let mut daddr = map.data_base;
    let mut laddr = map.local_base;
    // First pass: non-local globals.
    for (i, g) in m.globals.iter().enumerate() {
        let gid = GlobalId(i as u32);
        if g.space != AddrSpace::Local {
            info.addr.insert(gid, daddr);
            if let Some(init) = &g.init {
                data.push((daddr, init.clone()));
            }
            daddr += (g.size + 3) & !3;
        }
    }
    // Second pass: local-space globals.
    match smem {
        SharedMemMapping::Local => {
            for (i, g) in m.globals.iter().enumerate() {
                let gid = GlobalId(i as u32);
                if g.space == AddrSpace::Local {
                    info.addr.insert(gid, laddr);
                    laddr += (g.size + 3) & !3;
                }
            }
        }
        SharedMemMapping::Global => {
            // Per-core banks carved from the data segment.
            let total: u32 = m
                .globals
                .iter()
                .filter(|g| g.space == AddrSpace::Local)
                .map(|g| (g.size + 3) & !3)
                .sum();
            let stride = (total + 63) & !63;
            info.bank_stride = stride;
            let bank_base = (daddr + 63) & !63;
            let mut off = 0;
            for (i, g) in m.globals.iter().enumerate() {
                let gid = GlobalId(i as u32);
                if g.space == AddrSpace::Local {
                    info.addr.insert(gid, bank_base + off);
                    info.core_banked.insert(gid);
                    off += (g.size + 3) & !3;
                }
            }
            daddr = bank_base + stride * SMEM_MAX_CORES;
        }
    }
    (info, data, daddr, laddr - map.local_base)
}

/// Lower one function through the full back-end pipeline.
pub fn lower_function(
    m: &Module,
    fid: FuncId,
    layout: &LayoutInfo,
    opts: &BackendOptions,
) -> Result<MFunction, BackendError> {
    let mut mf = isel::select_function(m, fid, layout, opts)?;
    mir_opt::copy_prop(&mut mf);
    mir_opt::dce(&mut mf);
    if opts.codegen_opt {
        // The combine patterns expose copies and dead defs; run the
        // cleanups again so regalloc sees the slimmed function.
        combine::run(&mut mf);
        mir_opt::copy_prop(&mut mf);
        mir_opt::dce(&mut mf);
    }
    let ra_opts = if opts.codegen_opt {
        regalloc::RegAllocOptions::quality()
    } else {
        regalloc::RegAllocOptions::default()
    };
    regalloc::allocate_with(&mut mf, &opts.target.regfile, ra_opts);
    if opts.codegen_opt {
        // Coalesced copies are `mv r, r` after assignment.
        combine::cleanup_identities(&mut mf);
    }
    if opts.opt_layout {
        mir_opt::layout(&mut mf);
    }
    if opts.safety_net {
        let rep = safety_net::run(&mut mf, opts.zicond);
        if !rep.errors.is_empty() {
            return Err(BackendError::new(
                Some(mf.name.as_str()),
                format!("safety net rejected: {}", rep.errors.join("; ")),
            ));
        }
    }
    regalloc::finalize_frame(&mut mf);
    Ok(mf)
}

/// Flattened function: instructions + per-instruction block-target fixups.
struct FlatFunc {
    name: String,
    insts: Vec<MachInst>,
    /// Source location per emitted instruction (parallel to `insts`).
    locs: Vec<Option<Loc>>,
    /// Spill-traffic marker per emitted instruction (parallel to `insts`).
    spills: Vec<bool>,
    /// (inst index, kind) fixups to resolve once bases are known.
    fixups: Vec<(usize, Fixup)>,
    block_offset: Vec<u32>,
}

/// Line-table fill: PCs without a direct source location inherit the
/// nearest located instruction — forward first (the usual "still on the
/// previous source line" reading), then backward for a located-code
/// prefix (prologue/arg copies attribute to the first real line).
fn fill_locs(locs: &mut [Option<Loc>]) {
    let mut last: Option<Loc> = None;
    for l in locs.iter_mut() {
        match l {
            Some(x) => last = Some(*x),
            None => *l = last,
        }
    }
    let mut next: Option<Loc> = None;
    for l in locs.iter_mut().rev() {
        match l {
            Some(x) => next = Some(*x),
            None => *l = next,
        }
    }
}

enum Fixup {
    Branch(usize),          // t1 block (local)
    Split(usize, usize),    // else block, join block (local)
    PredExit(usize),        // t2 block (local)
    Call(String),           // callee entry
}

fn flatten(mf: &MFunction) -> FlatFunc {
    // First pass: block offsets, accounting for join coalescing and the
    // split/pred fallthrough fix-up jumps.
    let nb = mf.blocks.len();
    let mut block_offset = vec![0u32; nb];
    let mut size = 0u32;
    let sizes: Vec<u32> = (0..nb)
        .map(|bi| {
            let b = &mf.blocks[bi];
            let mut s = 0u32;
            let mut joins_seen = 0;
            for (k, i) in b.insts.iter().enumerate() {
                if i.op == Op::JOIN {
                    joins_seen += 1;
                    if joins_seen > 1 {
                        continue; // coalesced
                    }
                }
                s += 1;
                // Fallthrough fix-up after split/pred.
                if matches!(i.op, Op::SPLIT | Op::SPLITN | Op::PRED) {
                    let next_block = bi + 1;
                    if i.t1 != Some(next_block) || k + 1 != b.insts.len() {
                        s += 1; // explicit `j then/body`
                    }
                }
            }
            s
        })
        .collect();
    for bi in 0..nb {
        block_offset[bi] = size;
        size += sizes[bi];
    }
    // Second pass: emit.
    let mut insts: Vec<MachInst> = vec![];
    let mut locs: Vec<Option<Loc>> = vec![];
    let mut spills: Vec<bool> = vec![];
    let mut fixups: Vec<(usize, Fixup)> = vec![];
    for bi in 0..nb {
        let b = &mf.blocks[bi];
        let mut joins_seen = 0;
        for (k, i) in b.insts.iter().enumerate() {
            if i.op == Op::JOIN {
                joins_seen += 1;
                if joins_seen > 1 {
                    continue;
                }
            }
            let phys = |r: MReg| -> u8 {
                if r == NONE {
                    0
                } else {
                    debug_assert!(r.is_phys(), "unallocated vreg {r:?} in {}", mf.name);
                    r.0 as u8
                }
            };
            let mut mi = MachInst {
                op: i.op,
                rd: phys(i.rd),
                rs1: phys(i.rs1),
                rs2: phys(i.rs2),
                imm: i.imm as i32,
            };
            let idx = insts.len();
            match i.op {
                Op::J | Op::BEQZ | Op::BNEZ => {
                    fixups.push((idx, Fixup::Branch(i.t1.unwrap())));
                }
                Op::JAL => {
                    if let Some(c) = &i.callee {
                        fixups.push((idx, Fixup::Call(c.clone())));
                    } else {
                        fixups.push((idx, Fixup::Branch(i.t1.unwrap())));
                    }
                }
                Op::SPLIT | Op::SPLITN => {
                    fixups.push((idx, Fixup::Split(i.t2.unwrap(), i.tjoin.unwrap())));
                }
                Op::PRED => {
                    fixups.push((idx, Fixup::PredExit(i.t2.unwrap())));
                }
                Op::WSPAWN => {} // imm patched by crt0 builder only
                _ => {}
            }
            insts.push(mi);
            locs.push(i.loc);
            spills.push(i.spill);
            // Fallthrough fix-up jump.
            if matches!(i.op, Op::SPLIT | Op::SPLITN | Op::PRED) {
                let next_block = bi + 1;
                if i.t1 != Some(next_block) || k + 1 != b.insts.len() {
                    let jidx = insts.len();
                    insts.push(MachInst {
                        op: Op::J,
                        rd: 0,
                        rs1: 0,
                        rs2: 0,
                        imm: 0,
                    });
                    locs.push(i.loc);
                    spills.push(false);
                    fixups.push((jidx, Fixup::Branch(i.t1.unwrap())));
                }
            }
            let _ = &mut mi;
        }
    }
    debug_assert_eq!(insts.len(), locs.len());
    debug_assert_eq!(insts.len(), spills.len());
    fill_locs(&mut locs);
    FlatFunc {
        name: mf.name.clone(),
        insts,
        locs,
        spills,
        fixups,
        block_offset,
    }
}

/// Build the crt0 stub. The kernel entry PC is read from the argument
/// block at launch time (`__args + 24`), so one image serves every kernel
/// in the module and device memory persists across launches. Stack
/// geometry comes from the target's address map.
fn build_crt0(args_addr: u32, map: &AddressMap) -> (Vec<MachInst>, usize) {
    use Op::*;
    let x5 = 5u8;
    let x6 = 6u8;
    let sp = super::isa::SP;
    let ra = super::isa::RA;
    let mk = |op: Op, rd: u8, rs1: u8, rs2: u8, imm: i32| MachInst {
        op,
        rd,
        rs1,
        rs2,
        imm,
    };
    let mut c = vec![
        // warp 0, lane 0 only:
        mk(CSRR, x5, 0, 0, 4),      // x5 = NUM_WARPS
        mk(ADDI, x5, x5, 0, -1),    // x5 -= 1
        mk(WSPAWN, 0, x5, 0, 3),    // spawn warps 1.. at entry2 (index 3)
        // entry2:
        mk(LI, x6, 0, 0, -1),
        mk(TMC, 0, x6, 0, 0),       // all lanes on
        mk(CSRR, x5, 0, 0, 2),      // core_id
        mk(CSRR, x6, 0, 0, 4),      // num_warps
        mk(MUL, x5, x5, x6, 0),
        mk(CSRR, x6, 0, 0, 1),      // warp_id
        mk(ADD, x5, x5, x6, 0),
        mk(CSRR, x6, 0, 0, 3),      // num_threads
        mk(MUL, x5, x5, x6, 0),
        mk(CSRR, x6, 0, 0, 0),      // lane_id
        mk(ADD, x5, x5, x6, 0),     // gtid
        mk(LI, x6, 0, 0, map.stack_size as i32),
        mk(MUL, x5, x5, x6, 0),
        mk(LI, x6, 0, 0, (map.stack_base + map.stack_size) as i32),
        mk(ADD, sp, x5, x6, 0),     // sp = top of this thread's stack
        mk(LI, x6, 0, 0, args_addr as i32),
        mk(LW, x6, x6, 0, 24),      // kernel entry pc from __args
        mk(JALR, ra, x6, 0, 0),     // call dispatcher
        mk(TMC, 0, 0, 0, 0),        // x0 mask: warp retires
        mk(ECALL, 0, 0, 0, 0),      // unreachable guard
    ];
    let entry2 = 3usize;
    c[2].imm = entry2 as i32;
    let len = c.len();
    (c, len)
}

/// Link a complete image for one kernel dispatcher.
pub fn build_image(
    m: &Module,
    dispatcher: &str,
    opts: &BackendOptions,
) -> Result<ProgramImage, BackendError> {
    build_image_threaded(m, dispatcher, opts, 1)
}

/// [`build_image`] with per-function lowering fanned out across up to
/// `threads` scoped workers ([`crate::par`]). Functions are lowered
/// independently after dispatch; results join in call-graph order (and
/// the first error in that order wins), so the linked image — words,
/// line table, spill map, entries — is byte-identical to the
/// sequential build for any thread count. Linking, fixups and the
/// feature audit stay sequential.
pub fn build_image_threaded(
    m: &Module,
    dispatcher: &str,
    opts: &BackendOptions,
    threads: usize,
) -> Result<ProgramImage, BackendError> {
    let entry_fid = m.find_func(dispatcher).ok_or_else(|| {
        BackendError::new(Some(dispatcher), "unknown kernel entry")
    })?;
    let map = opts.target.addr_map;
    let (layout, data, data_end, _local_static) = layout_globals(m, opts.smem, &map);
    // Reachable functions — from *every* kernel so one image serves all
    // launches of this module.
    let cg = crate::analysis::callgraph::CallGraph::build(m);
    let mut roots = m.kernels();
    if !roots.contains(&entry_fid) {
        roots.push(entry_fid);
    }
    let order = cg.rpo_from(&roots);
    let lowered = crate::par::par_map(&order, threads, |_, fid| {
        let mf = lower_function(m, *fid, &layout, opts)?;
        Ok::<(u32, FlatFunc), BackendError>((mf.local_mem_size, flatten(&mf)))
    });
    let mut flats: Vec<FlatFunc> = vec![];
    let mut local_mem = 0u32;
    for r in lowered {
        let (lm, flat) = r?;
        local_mem = local_mem.max(lm);
        flats.push(flat);
    }
    // crt0 + function bases. The args block address is known from layout.
    let args_probe = m.globals.iter().position(|g| g.name == "__args").ok_or_else(|| {
        BackendError::new(None, "module has no __args block (schedule pass not run?)")
    })?;
    let args_addr_v = layout.addr[&GlobalId(args_probe as u32)];
    let (mut code, crt0_len) = build_crt0(args_addr_v, &map);
    // crt0 is runtime startup, not source code: no line-table entries
    // and no spill traffic.
    let mut pc_loc: Vec<Option<Loc>> = vec![None; crt0_len];
    let mut pc_spill: Vec<bool> = vec![false; crt0_len];
    let mut func_entries: HashMap<String, u32> = HashMap::new();
    for fl in &flats {
        func_entries.insert(fl.name.clone(), code.len() as u32);
        code.extend(fl.insts.iter().cloned());
        pc_loc.extend(fl.locs.iter().cloned());
        pc_spill.extend(fl.spills.iter().cloned());
    }
    if !func_entries.contains_key(dispatcher) {
        return Err(BackendError::new(
            Some(dispatcher),
            "dispatcher dropped during lowering",
        ));
    }
    // Resolve fixups.
    let mut cursor = crt0_len as u32;
    for fl in &flats {
        let base = cursor;
        for (idx, fx) in &fl.fixups {
            let gidx = base + *idx as u32;
            let inst = &mut code[gidx as usize];
            match fx {
                Fixup::Branch(b) => inst.imm = (base + fl.block_offset[*b]) as i32,
                Fixup::Split(else_b, join_b) => {
                    inst.imm = MachInst::pack_split(
                        base + fl.block_offset[*else_b],
                        base + fl.block_offset[*join_b],
                    );
                }
                Fixup::PredExit(b) => inst.imm = (base + fl.block_offset[*b]) as i32,
                Fixup::Call(name) => {
                    inst.imm = *func_entries.get(name).ok_or_else(|| {
                        BackendError::new(
                            Some(fl.name.as_str()),
                            format!("unresolved call to '{name}'"),
                        )
                    })? as i32;
                }
            }
        }
        cursor += fl.insts.len() as u32;
    }
    // Final image audit: no instruction may use an extension the target
    // lacks. isel already refuses per-function; this catches anything a
    // later MIR pass or crt0 could introduce, making "no vx_cmov in a
    // vortex-min image" a structural guarantee, not a convention.
    for (pc, inst) in code.iter().enumerate() {
        if !opts.target.supports_op(inst.op) {
            let gate = crate::target::Features::gate_name(inst.op).unwrap_or("?");
            return Err(BackendError::new(
                Some(dispatcher),
                format!(
                    "linked image contains '{}' at pc {pc}, but target '{}' lacks the \
                     '{gate}' extension",
                    inst.op.mnemonic(),
                    opts.target.name
                ),
            ));
        }
    }
    let words: Vec<u64> = code.iter().map(|i| i.encode()).collect();
    // Global name table.
    let mut global_addr = HashMap::new();
    let mut global_size = HashMap::new();
    for (i, g) in m.globals.iter().enumerate() {
        global_addr.insert(g.name.clone(), layout.addr[&GlobalId(i as u32)]);
        global_size.insert(g.name.clone(), g.size);
    }
    let args_addr = *global_addr.get("__args").ok_or_else(|| {
        BackendError::new(None, "module has no __args block (schedule pass not run?)")
    })?;
    // Account local memory from globals too.
    let local_from_globals: u32 = m
        .globals
        .iter()
        .filter(|g| g.space == AddrSpace::Local)
        .map(|g| (g.size + 3) & !3)
        .sum();
    Ok(ProgramImage {
        code,
        words,
        data,
        data_end,
        global_addr,
        global_size,
        args_addr,
        local_mem_size: local_mem.max(local_from_globals),
        kernel: dispatcher.to_string(),
        func_entries,
        pc_loc,
        crt0_len: crt0_len as u32,
        pc_spill,
        target: opts.target.name.to_string(),
        addr_map: map,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::{compile_kernels, FrontendOptions};
    use crate::transform::{run_middle_end, OptLevel};

    fn build(src: &str) -> ProgramImage {
        let (mut m, infos) = compile_kernels(src, &FrontendOptions::default()).unwrap();
        let mut cfg = OptLevel::Recon.config();
        cfg.verify = true;
        run_middle_end(&mut m, &cfg);
        build_image(
            &m,
            &format!("__main_{}", infos[0].name),
            &BackendOptions::default(),
        )
        .unwrap()
    }

    #[test]
    fn builds_saxpy_image() {
        let img = build(
            r#"
kernel void saxpy(global float* x, global float* y, float a, int n) {
    int i = get_global_id(0);
    if (i < n) { y[i] = a * x[i] + y[i]; }
}
"#,
        );
        assert!(img.code.len() > 30);
        assert!(img.func_entries.contains_key("__main_saxpy"));
        assert!(img.global_addr.contains_key("__args"));
        // Round-trip encode/decode.
        for (w, i) in img.words.iter().zip(img.code.iter()) {
            assert_eq!(MachInst::decode(*w), Some(*i));
        }
        // The image contains divergence management (tail guard).
        assert!(img
            .code
            .iter()
            .any(|i| matches!(i.op, Op::SPLIT | Op::SPLITN)));
        assert!(img.code.iter().any(|i| i.op == Op::JOIN));
        // crt0 begins with the spawn sequence.
        assert_eq!(img.code[2].op, Op::WSPAWN);
        let dis = img.disassemble();
        assert!(dis.contains("vx_split"));
        // Line table: parallel to code, empty over crt0, filled over the
        // compiled functions (kernel body lines 3/4 of the source above).
        assert_eq!(img.pc_loc.len(), img.code.len());
        assert!(img.crt0_len > 0);
        assert!(img.pc_loc[..img.crt0_len as usize].iter().all(|l| l.is_none()));
        let body = &img.pc_loc[img.crt0_len as usize..];
        assert!(body.iter().all(|l| l.is_some()), "line-table fill left gaps");
        assert!(
            body.iter().any(|l| l.map(|x| x.line) == Some(4)),
            "kernel body line 4 missing from the line table"
        );
    }

    #[test]
    fn split_fixups_point_at_joins() {
        let img = build(
            r#"
kernel void k(global int* out, int n) {
    int i = get_global_id(0);
    if (i % 3 == 0) { out[i] = 1; } else { out[i] = 2; }
}
"#,
        );
        for inst in &img.code {
            if matches!(inst.op, Op::SPLIT | Op::SPLITN) {
                let (else_i, join_i) = MachInst::split_targets(inst.imm);
                assert!((else_i as usize) < img.code.len());
                assert_eq!(img.code[join_i as usize].op, Op::JOIN, "join target must be a JOIN");
            }
        }
    }

    /// Cross-target legalization at the image level: the same kernel
    /// compiled for vortex keeps its select as vx_cmov, while the
    /// vortex-min image is proven free of every gated extension op.
    #[test]
    fn vortex_min_image_has_no_gated_ops() {
        let src = r#"
kernel void k(global int* out, int n) {
    int i = get_global_id(0);
    int v = 0;
    if (i % 2 == 0) { v = i * 3; } else { v = i + 7; }
    if (i < n) out[i] = v;
}
"#;
        let (mut mv, infos) = compile_kernels(src, &FrontendOptions::default()).unwrap();
        let mut mm = mv.clone();
        let dispatcher = format!("__main_{}", infos[0].name);
        // vortex @ Recon (zicond on): select survives to vx_cmov.
        let mut cfg = OptLevel::Recon.config();
        cfg.verify = true;
        run_middle_end(&mut mv, &cfg);
        let img_v = build_image(&mv, &dispatcher, &BackendOptions::default()).unwrap();
        assert_eq!(img_v.target, "vortex");
        assert!(
            img_v.code.iter().any(|i| i.op == Op::CMOV),
            "vortex image should retain the formed select as vx_cmov"
        );
        // vortex-min: the middle-end legalizes selects to branches and the
        // linked image contains no gated op at all.
        let min = crate::target::TargetDesc::vortex_min();
        let mut cfg_min = OptLevel::Recon.config();
        cfg_min.features = min.features;
        cfg_min.verify = true;
        run_middle_end(&mut mm, &cfg_min);
        let img_m = build_image(
            &mm,
            &dispatcher,
            &BackendOptions {
                zicond: false,
                target: min,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(img_m.target, "vortex-min");
        for inst in &img_m.code {
            assert!(
                min.supports_op(inst.op),
                "gated op {:?} leaked into a vortex-min image",
                inst.op
            );
        }
        assert!(img_m.code.iter().all(|i| i.op != Op::CMOV));
        assert_eq!(img_m.addr_map, min.addr_map);
    }

    /// The backend codegen rung folds the `li` before every `__args`
    /// load into an absolute `lw addr(x0)`, shrinking the image; the
    /// spill table stays parallel to the code either way.
    #[test]
    fn codegen_opt_folds_absolute_addresses() {
        let src = r#"
kernel void k(global int* out, int n) {
    int i = get_global_id(0);
    if (i < n) { out[i] = i * 2 + 1; }
}
"#;
        let (mut m, infos) = compile_kernels(src, &FrontendOptions::default()).unwrap();
        let mut cfg = OptLevel::Recon.config();
        cfg.verify = true;
        run_middle_end(&mut m, &cfg);
        let dispatcher = format!("__main_{}", infos[0].name);
        let on = build_image(&m, &dispatcher, &BackendOptions::default()).unwrap();
        let off = build_image(
            &m,
            &dispatcher,
            &BackendOptions {
                codegen_opt: false,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            on.code.len() < off.code.len(),
            "combine must shrink the image ({} !< {})",
            on.code.len(),
            off.code.len()
        );
        assert!(
            on.code[on.crt0_len as usize..]
                .iter()
                .any(|i| i.op == Op::LW && i.rs1 == 0 && i.imm > 0),
            "expected an absolute lw addr(x0) after x0-folding"
        );
        for img in [&on, &off] {
            assert_eq!(img.pc_spill.len(), img.code.len());
            assert!(img.pc_spill[..img.crt0_len as usize].iter().all(|&s| !s));
            // Spill-tagged PCs can only be memory traffic.
            for (pc, &s) in img.pc_spill.iter().enumerate() {
                if s {
                    assert!(
                        matches!(img.code[pc].op, Op::LW | Op::SW),
                        "non-memory op tagged as spill at pc {pc}"
                    );
                }
            }
        }
    }

    #[test]
    fn data_layout_includes_constants() {
        let img = build(
            r#"
__constant__ float lut[2] = { 1.5f, 2.5f };
kernel void k(global float* out) {
    out[get_global_id(0)] = lut[0];
}
"#,
        );
        assert!(img.global_addr.contains_key("lut"));
        let lut_addr = img.global_addr["lut"];
        let seg = img.data.iter().find(|(a, _)| *a == lut_addr).unwrap();
        assert_eq!(&seg.1[0..4], &1.5f32.to_bits().to_le_bytes());
    }
}
