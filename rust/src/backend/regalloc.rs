//! Linear-scan register allocation with spilling.
//!
//! Whole-interval linear scan (Poletto–Sarkar) over the MIR: liveness from
//! the per-block dataflow in [`super::mir::liveness`], intervals extended
//! across loop back edges. Values live across calls are spilled (the ABI
//! treats every register as caller-saved; the middle-end's inlining makes
//! surviving calls rare). Spilled values are rematerialized through
//! reserved scratch registers (x30/x31, f30/f31).

use super::isa::Op;
use super::mir::{liveness, MFunction, MInst, MReg};
use crate::target::RegFile;
use std::collections::HashMap;

const T5: u32 = 30;
const T6: u32 = 31;
/// Scratch for spilled read-modify-write destinations (CMOV/AMOCAS): must
/// not collide with the rs1/rs2 reload scratches.
const T7: u32 = 29;
const FT5: u32 = 62;
const FT6: u32 = 63;
const FT7: u32 = 61;

#[derive(Debug, Default)]
pub struct RegAllocReport {
    pub assigned: usize,
    pub spilled: usize,
}

struct Interval {
    vreg: MReg,
    start: u32,
    end: u32,
    float: bool,
    crosses_call: bool,
}

pub fn allocate(f: &mut MFunction, rf: &RegFile) -> RegAllocReport {
    let mut report = RegAllocReport::default();
    // Linear numbering.
    let mut pos = 0u32;
    let mut block_range: Vec<(u32, u32)> = vec![];
    let mut call_positions: Vec<u32> = vec![];
    for b in &f.blocks {
        let s = pos;
        for i in &b.insts {
            if i.is_call() {
                call_positions.push(pos);
            }
            pos += 1;
        }
        block_range.push((s, pos));
    }
    let (live_in, live_out) = liveness(f);
    // Build intervals.
    let mut ivs: HashMap<MReg, (u32, u32)> = HashMap::new();
    let extend = |r: MReg, p: u32, ivs: &mut HashMap<MReg, (u32, u32)>| {
        let e = ivs.entry(r).or_insert((p, p));
        e.0 = e.0.min(p);
        e.1 = e.1.max(p);
    };
    let mut pos = 0u32;
    for (bi, b) in f.blocks.iter().enumerate() {
        for r in live_in[bi].iter() {
            extend(*r, block_range[bi].0, &mut ivs);
        }
        for r in live_out[bi].iter() {
            extend(*r, block_range[bi].1.saturating_sub(1).max(block_range[bi].0), &mut ivs);
        }
        for i in &b.insts {
            for u in i.uses() {
                if u.is_virt() {
                    extend(u, pos, &mut ivs);
                }
            }
            if let Some(d) = i.def() {
                if d.is_virt() {
                    extend(d, pos, &mut ivs);
                }
            }
            pos += 1;
        }
    }
    let mut intervals: Vec<Interval> = ivs
        .into_iter()
        .map(|(vreg, (start, end))| Interval {
            vreg,
            start,
            end,
            float: f.is_float(vreg),
            crosses_call: call_positions.iter().any(|&c| start < c && c < end),
        })
        .collect();
    intervals.sort_by_key(|iv| iv.start);

    // Register pools from the target's register-file shape (scratch +
    // special registers sit outside the allocatable windows). Functions
    // with calls additionally avoid the ABI argument registers. All
    // window arithmetic is u32 and half-open so a custom RegFile with
    // arg_count == 0 (or a window at the type boundary) cannot wrap.
    let args = rf.arg_base as u32..rf.arg_base as u32 + rf.arg_count as u32;
    let fargs = rf.float_base as u32 + rf.arg_base as u32
        ..rf.float_base as u32 + rf.arg_base as u32 + rf.arg_count as u32;
    let int_pool: Vec<u32> = (rf.int_alloc.0 as u32..=rf.int_alloc.1 as u32)
        .filter(|r| !f.has_calls || !args.contains(r))
        .collect();
    let float_pool: Vec<u32> = (rf.float_alloc.0 as u32..=rf.float_alloc.1 as u32)
        .filter(|r| !f.has_calls || !fargs.contains(r))
        .collect();

    let mut assignment: HashMap<MReg, u32> = HashMap::new();
    let mut spills: HashMap<MReg, u32> = HashMap::new(); // vreg -> slot index
    let mut next_slot = 0u32;
    let mut active: Vec<(u32 /*end*/, MReg, u32 /*phys*/)> = vec![];
    let mut free_int = int_pool.clone();
    let mut free_float = float_pool.clone();
    for iv in &intervals {
        // Expire.
        active.retain(|&(end, _, phys)| {
            if end < iv.start {
                if phys >= 32 {
                    free_float.push(phys);
                } else {
                    free_int.push(phys);
                }
                false
            } else {
                true
            }
        });
        if iv.crosses_call {
            spills.insert(iv.vreg, next_slot);
            next_slot += 1;
            report.spilled += 1;
            continue;
        }
        let pool = if iv.float { &mut free_float } else { &mut free_int };
        if let Some(phys) = pool.pop() {
            assignment.insert(iv.vreg, phys);
            active.push((iv.end, iv.vreg, phys));
            report.assigned += 1;
        } else {
            // Spill the interval with the furthest end (current or active
            // of the same class).
            let victim = active
                .iter()
                .enumerate()
                .filter(|(_, (_, _, p))| (*p >= 32) == iv.float)
                .max_by_key(|(_, (end, _, _))| *end);
            match victim {
                Some((ai, &(aend, avreg, aphys))) if aend > iv.end => {
                    active.remove(ai);
                    assignment.remove(&avreg);
                    spills.insert(avreg, next_slot);
                    next_slot += 1;
                    report.spilled += 1;
                    assignment.insert(iv.vreg, aphys);
                    active.push((iv.end, iv.vreg, aphys));
                }
                _ => {
                    spills.insert(iv.vreg, next_slot);
                    next_slot += 1;
                    report.spilled += 1;
                }
            }
        }
    }
    f.spill_size = next_slot * 4;

    // Rewrite: apply assignments, insert spill loads/stores.
    let frame_base = f.frame_size; // spill slots sit above the allocas
    for b in f.blocks.iter_mut() {
        let mut out: Vec<MInst> = Vec::with_capacity(b.insts.len());
        for inst in b.insts.drain(..) {
            let mut i = inst;
            let mut pre: Vec<MInst> = vec![];
            let mut post: Vec<MInst> = vec![];
            let map_use = |r: MReg,
                           scratch: u32,
                           pre: &mut Vec<MInst>,
                           assignment: &HashMap<MReg, u32>,
                           spills: &HashMap<MReg, u32>|
             -> MReg {
                if !r.is_virt() {
                    return r;
                }
                if let Some(&p) = assignment.get(&r) {
                    return MReg(p);
                }
                let slot = spills[&r];
                pre.push(MInst::rri(
                    Op::LW,
                    MReg(scratch),
                    MReg::phys(super::isa::SP),
                    (frame_base + slot * 4) as i64,
                ));
                MReg(scratch)
            };
            // rd-as-use ops (CMOV, AMOCAS) read rd too.
            let rd_is_use = matches!(i.op, Op::CMOV | Op::AMOCAS);
            if !i.rs1.is_none() {
                let sc = if i.rs1.is_virt() && f.vreg_float[i.rs1.virt_idx()] {
                    FT5
                } else {
                    T5
                };
                i.rs1 = map_use(i.rs1, sc, &mut pre, &assignment, &spills);
            }
            if !i.rs2.is_none() {
                let sc = if i.rs2.is_virt() && f.vreg_float[i.rs2.virt_idx()] {
                    FT6
                } else {
                    T6
                };
                i.rs2 = map_use(i.rs2, sc, &mut pre, &assignment, &spills);
            }
            if !i.rd.is_none() && i.rd.is_virt() {
                let r = i.rd;
                if let Some(&p) = assignment.get(&r) {
                    i.rd = MReg(p);
                } else {
                    let slot = spills[&r];
                    // rd shares the instruction with rs1/rs2 reloads when it
                    // is also a source (CMOV/AMOCAS): use a dedicated
                    // scratch so the pre-load cannot clobber them.
                    let sc = match (rd_is_use, f.vreg_float[r.virt_idx()]) {
                        (true, true) => FT7,
                        (true, false) => T7,
                        (false, true) => FT5,
                        (false, false) => T5,
                    };
                    if rd_is_use {
                        pre.push(MInst::rri(
                            Op::LW,
                            MReg(sc),
                            MReg::phys(super::isa::SP),
                            (frame_base + slot * 4) as i64,
                        ));
                    }
                    i.rd = MReg(sc);
                    if i.def().is_some() {
                        post.push(MInst {
                            op: Op::SW,
                            rd: super::mir::NONE,
                            rs1: MReg::phys(super::isa::SP),
                            rs2: MReg(sc),
                            imm: (frame_base + slot * 4) as i64,
                            ..MInst::new(Op::SW)
                        });
                    }
                }
            }
            out.extend(pre);
            out.push(i);
            out.extend(post);
        }
        b.insts = out;
    }
    report
}

/// Insert prologue/epilogue once frame + spill sizes are final.
pub fn finalize_frame(f: &mut MFunction) {
    let ra_bytes = if f.has_calls { 4 } else { 0 };
    let total = (f.frame_size + f.spill_size + ra_bytes + 7) & !7;
    if total == 0 {
        return;
    }
    let sp = MReg::phys(super::isa::SP);
    let ra = MReg::phys(super::isa::RA);
    // Prologue at the very beginning.
    let mut pro = vec![MInst::rri(Op::ADDI, sp, sp, -(total as i64))];
    if f.has_calls {
        pro.push(MInst {
            op: Op::SW,
            rd: super::mir::NONE,
            rs1: sp,
            rs2: ra,
            imm: (total - 4) as i64,
            ..MInst::new(Op::SW)
        });
    }
    let entry = &mut f.blocks[0].insts;
    for (k, p) in pro.into_iter().enumerate() {
        entry.insert(k, p);
    }
    // Epilogue before every return (JALR x0, ra).
    for b in f.blocks.iter_mut() {
        let mut k = 0;
        while k < b.insts.len() {
            let is_ret = b.insts[k].op == Op::JALR
                && b.insts[k].rd == MReg::phys(0)
                && b.insts[k].callee.is_none();
            if is_ret {
                let mut epi = vec![];
                if f.has_calls {
                    epi.push(MInst::rri(Op::LW, ra, sp, (total - 4) as i64));
                }
                epi.push(MInst::rri(Op::ADDI, sp, sp, total as i64));
                for (j, e) in epi.into_iter().enumerate() {
                    b.insts.insert(k + j, e);
                    k += 1;
                }
            }
            k += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::mir::MBlock;

    fn func_with_pressure(n: usize) -> MFunction {
        // n live values summed at the end — forces spills for large n.
        let mut f = MFunction {
            name: "t".into(),
            blocks: vec![MBlock::default()],
            vreg_float: vec![],
            frame_size: 0,
            spill_size: 0,
            has_calls: false,
            local_mem_size: 0,
        };
        let regs: Vec<MReg> = (0..n).map(|_| f.new_vreg(false)).collect();
        for (k, &r) in regs.iter().enumerate() {
            f.blocks[0].insts.push(MInst::li(r, k as i64));
        }
        let acc = f.new_vreg(false);
        f.blocks[0].insts.push(MInst::li(acc, 0));
        for &r in &regs {
            f.blocks[0].insts.push(MInst::rrr(Op::ADD, acc, acc, r));
        }
        let mut ret = MInst::new(Op::JALR);
        ret.rd = MReg::phys(0);
        ret.rs1 = MReg::phys(super::super::isa::RA);
        f.blocks[0].insts.push(MInst::mv(MReg::phys(10), acc));
        f.blocks[0].insts.push(ret);
        f
    }

    #[test]
    fn allocates_without_spills_when_fits() {
        let mut f = func_with_pressure(8);
        let rep = allocate(&mut f, &RegFile::vortex());
        assert_eq!(rep.spilled, 0);
        // No virtual registers remain.
        for b in &f.blocks {
            for i in &b.insts {
                assert!(!i.rd.is_virt() && !i.rs1.is_virt() && !i.rs2.is_virt(), "{i:?}");
            }
        }
    }

    #[test]
    fn spills_under_pressure() {
        let mut f = func_with_pressure(40);
        let rep = allocate(&mut f, &RegFile::vortex());
        assert!(rep.spilled > 0);
        assert!(f.spill_size >= 4 * rep.spilled as u32);
        for b in &f.blocks {
            for i in &b.insts {
                assert!(!i.rd.is_virt() && !i.rs1.is_virt() && !i.rs2.is_virt(), "{i:?}");
            }
        }
        // Spill traffic exists.
        assert!(f.blocks[0].insts.iter().any(|i| i.op == Op::SW));
        assert!(f.blocks[0].insts.iter().any(|i| i.op == Op::LW));
    }

    /// The allocator pools come from the target's register-file shape: a
    /// narrower allocatable window spills where the full file does not.
    #[test]
    fn pools_follow_regfile_shape() {
        let narrow = RegFile {
            int_alloc: (5, 12),
            ..RegFile::vortex()
        };
        let mut f = func_with_pressure(12);
        let rep = allocate(&mut f, &narrow);
        assert!(rep.spilled > 0, "13 live values cannot fit 8 allocatable regs");
        for b in &f.blocks {
            for i in &b.insts {
                for r in [i.rd, i.rs1, i.rs2] {
                    assert!(!r.is_virt());
                }
            }
        }
        let mut f2 = func_with_pressure(12);
        assert_eq!(allocate(&mut f2, &RegFile::vortex()).spilled, 0);
    }

    #[test]
    fn call_crossing_values_are_spilled() {
        let mut f = MFunction {
            name: "t".into(),
            blocks: vec![MBlock::default()],
            vreg_float: vec![],
            frame_size: 0,
            spill_size: 0,
            has_calls: true,
            local_mem_size: 0,
        };
        let v = f.new_vreg(false);
        f.blocks[0].insts.push(MInst::li(v, 42));
        let mut call = MInst::new(Op::JAL);
        call.rd = MReg::phys(super::super::isa::RA);
        call.callee = Some("g".into());
        f.blocks[0].insts.push(call);
        f.blocks[0].insts.push(MInst::mv(MReg::phys(10), v)); // use after call
        let mut ret = MInst::new(Op::JALR);
        ret.rd = MReg::phys(0);
        ret.rs1 = MReg::phys(super::super::isa::RA);
        f.blocks[0].insts.push(ret);
        let rep = allocate(&mut f, &RegFile::vortex());
        assert_eq!(rep.spilled, 1);
        finalize_frame(&mut f);
        // prologue adjusts sp and saves ra.
        assert_eq!(f.blocks[0].insts[0].op, Op::ADDI);
        assert!(f.blocks[0].insts[1].op == Op::SW);
    }
}
