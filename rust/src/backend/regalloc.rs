//! Linear-scan register allocation with spilling.
//!
//! The engine builds per-vreg **live ranges** (half-open position
//! intervals over a use/def-slotted numbering: instruction `g` reads at
//! `2g` and writes at `2g+1`) from the per-block dataflow in
//! [`super::mir::liveness`]. Three quality features sit behind
//! [`RegAllocOptions`] (the backend codegen rung enables all of them;
//! the default mimics the seed Poletto–Sarkar whole-interval scan so
//! baselines stay comparable):
//!
//! * **holes** — a value dead across a region (e.g. across a loop it is
//!   not used in) releases its register there instead of occupying it
//!   for the whole envelope. Per-lane sound: lanes follow CFG edges, so
//!   a lane that executes a clobber inside a hole can never reach a use
//!   of the holed value afterwards (the value is CFG-dead there).
//! * **coalescing** — virtual `mv d, s` copies (isel select/CAS
//!   prologues, phi-destruction copies) merge `d` and `s` into one
//!   interval when their range sets do not interfere; after assignment
//!   the copy is `mv r, r` and `combine::cleanup_identities` drops it.
//! * **Belady spill choice** — under pressure the victim is the value
//!   with the *furthest next use* instead of the longest interval end,
//!   so loop-carried values stop losing their registers to long-lived
//!   cold values.
//!
//! Values live across calls are spilled (the ABI treats every register
//! as caller-saved; the middle-end's inlining makes surviving calls
//! rare). Spilled values are rematerialized through reserved scratch
//! registers (x30/x31 for sources, x29 for read-modify-write
//! destinations — CMOV/AMOCAS read `rd` too, so the reload must not
//! collide with the rs1/rs2 scratches; f61–f63 mirror this for floats).
//! Spill loads/stores are tagged (`MInst::spill`) so the emitter can
//! publish per-PC spill traffic to the profiler.

use super::isa::Op;
use super::mir::{liveness, MFunction, MInst, MReg};
use crate::target::RegFile;
use std::collections::HashMap;

const T5: u32 = 30;
const T6: u32 = 31;
/// Scratch for spilled read-modify-write destinations (CMOV/AMOCAS): must
/// not collide with the rs1/rs2 reload scratches.
const T7: u32 = 29;
const FT5: u32 = 62;
const FT6: u32 = 63;
const FT7: u32 = 61;

/// Quality switches for the allocator (see module docs). `default()` is
/// the seed behavior; [`RegAllocOptions::quality`] is the codegen rung.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RegAllocOptions {
    /// Keep per-block live-range holes.
    pub holes: bool,
    /// Coalesce virtual copies by interval merging.
    pub coalesce: bool,
    /// Furthest-next-use (Belady) spill victims.
    pub spill_next_use: bool,
}

impl RegAllocOptions {
    pub fn quality() -> RegAllocOptions {
        RegAllocOptions {
            holes: true,
            coalesce: true,
            spill_next_use: true,
        }
    }
}

#[derive(Debug, Default)]
pub struct RegAllocReport {
    pub assigned: usize,
    pub spilled: usize,
    /// Virtual copies folded away by interval merging.
    pub coalesced: usize,
}

/// Seed-compatible entry point (whole intervals, longest-end spilling).
pub fn allocate(f: &mut MFunction, rf: &RegFile) -> RegAllocReport {
    allocate_with(f, rf, RegAllocOptions::default())
}

pub fn allocate_with(f: &mut MFunction, rf: &RegFile, opts: RegAllocOptions) -> RegAllocReport {
    let mut report = RegAllocReport::default();
    let nv = f.vreg_float.len();
    let nb = f.blocks.len();

    // Global instruction numbering and call positions.
    let mut block_start = vec![0u32; nb];
    let mut call_positions: Vec<u32> = vec![];
    {
        let mut g = 0u32;
        for (bi, b) in f.blocks.iter().enumerate() {
            block_start[bi] = g;
            for i in &b.insts {
                if i.is_call() {
                    call_positions.push(g);
                }
                g += 1;
            }
        }
    }

    // ---- Live-range construction (positions: use = 2g, def = 2g+1). ----
    let (_live_in, live_out) = liveness(f);
    let mut ranges: Vec<Vec<(u32, u32)>> = vec![vec![]; nv];
    let mut use_pos: Vec<Vec<u32>> = vec![vec![]; nv];
    for bi in 0..nb {
        let gs = block_start[bi];
        let len = f.blocks[bi].insts.len() as u32;
        let (bs, be) = (2 * gs, 2 * (gs + len));
        // vreg -> end of the currently-open range in this block.
        let mut open: HashMap<usize, u32> = live_out[bi]
            .iter()
            .filter(|r| r.is_virt())
            .map(|r| (r.virt_idx(), be))
            .collect();
        for k in (0..f.blocks[bi].insts.len()).rev() {
            let g = gs + k as u32;
            let inst = &f.blocks[bi].insts[k];
            if let Some(d) = inst.def() {
                if d.is_virt() {
                    let vi = d.virt_idx();
                    let end = open.remove(&vi).unwrap_or(2 * g + 2);
                    ranges[vi].push((2 * g + 1, end.max(2 * g + 2)));
                    use_pos[vi].push(2 * g + 1);
                }
            }
            for u in inst.uses() {
                if u.is_virt() {
                    let vi = u.virt_idx();
                    open.entry(vi).or_insert(2 * g + 1);
                    use_pos[vi].push(2 * g);
                }
            }
        }
        for (vi, end) in open {
            ranges[vi].push((bs, end));
        }
    }
    for v in 0..nv {
        normalize(&mut ranges[v]);
        use_pos[v].sort_unstable();
        if !opts.holes && !ranges[v].is_empty() {
            // Whole-interval envelope (seed behavior).
            let s = ranges[v][0].0;
            let e = ranges[v].last().unwrap().1;
            ranges[v] = vec![(s, e)];
        }
    }

    // ---- Copy coalescing (union-find; ranges live on the root). ----
    let mut parent: Vec<usize> = (0..nv).collect();
    fn find(parent: &mut [usize], mut v: usize) -> usize {
        while parent[v] != v {
            parent[v] = parent[parent[v]];
            v = parent[v];
        }
        v
    }
    if opts.coalesce {
        for b in &f.blocks {
            for i in &b.insts {
                if i.op != Op::MOV || !i.rd.is_virt() || !i.rs1.is_virt() {
                    continue;
                }
                let (d, s) = (i.rd.virt_idx(), i.rs1.virt_idx());
                if f.vreg_float[d] != f.vreg_float[s] {
                    continue;
                }
                let (rd, rs) = (find(&mut parent, d), find(&mut parent, s));
                if rd == rs {
                    continue;
                }
                if ranges_overlap(&ranges[rd], &ranges[rs]) {
                    continue;
                }
                // Merge rs into rd.
                let taken = std::mem::take(&mut ranges[rs]);
                ranges[rd].extend(taken);
                normalize(&mut ranges[rd]);
                let taken_uses = std::mem::take(&mut use_pos[rs]);
                use_pos[rd].extend(taken_uses);
                use_pos[rd].sort_unstable();
                parent[rs] = rd;
                report.coalesced += 1;
            }
        }
    }

    // ---- Interval list (roots only), in start order. ----
    struct Iv {
        root: usize,
        start: u32,
        end: u32,
        float: bool,
        crosses_call: bool,
    }
    let mut intervals: Vec<Iv> = vec![];
    for v in 0..nv {
        if parent[v] != v || ranges[v].is_empty() {
            continue;
        }
        let start = ranges[v][0].0;
        let end = ranges[v].last().unwrap().1;
        let crosses_call = call_positions.iter().any(|&c| {
            let p = 2 * c + 1;
            ranges[v].iter().any(|&(s, e)| s < p && e > p + 1)
        });
        intervals.push(Iv {
            root: v,
            start,
            end,
            float: f.vreg_float[v],
            crosses_call,
        });
    }
    intervals.sort_by_key(|iv| (iv.start, iv.root));

    // Register pools from the target's register-file shape (scratch +
    // special registers sit outside the allocatable windows). Functions
    // with calls additionally avoid the ABI argument registers. All
    // window arithmetic is u32 and half-open so a custom RegFile with
    // arg_count == 0 (or a window at the type boundary) cannot wrap.
    let args = rf.arg_base as u32..rf.arg_base as u32 + rf.arg_count as u32;
    let fargs = rf.float_base as u32 + rf.arg_base as u32
        ..rf.float_base as u32 + rf.arg_base as u32 + rf.arg_count as u32;
    let int_pool: Vec<u32> = (rf.int_alloc.0 as u32..=rf.int_alloc.1 as u32)
        .filter(|r| !f.has_calls || !args.contains(r))
        .collect();
    let float_pool: Vec<u32> = (rf.float_alloc.0 as u32..=rf.float_alloc.1 as u32)
        .filter(|r| !f.has_calls || !fargs.contains(r))
        .collect();

    let mut assignment: HashMap<usize, u32> = HashMap::new(); // root -> phys
    let mut spills: HashMap<usize, u32> = HashMap::new(); // root -> slot
    let mut next_slot = 0u32;
    // phys -> currently-relevant roots. Intervals are processed in
    // start order, so roots whose envelope ended before the current
    // start can never conflict again and are pruned each step (the
    // seed's active-list expiry, keeping the fit/eviction scans linear
    // in *live* intervals rather than all prior ones).
    let mut assigned_to: HashMap<u32, Vec<usize>> = HashMap::new();

    // First use at or after `pos` (Belady distance).
    let next_use_after = |root: usize, pos: u32, strict: bool| -> u64 {
        match use_pos[root]
            .iter()
            .find(|&&u| if strict { u > pos } else { u >= pos })
        {
            Some(&u) => u as u64,
            None => u64::MAX,
        }
    };

    for iv in &intervals {
        if iv.crosses_call {
            spills.insert(iv.root, next_slot);
            next_slot += 1;
            report.spilled += 1;
            continue;
        }
        // Expire: drop roots whose last range ended at or before this
        // interval's start.
        for roots in assigned_to.values_mut() {
            roots.retain(|&o| ranges[o].last().is_some_and(|&(_, e)| e > iv.start));
        }
        let pool = if iv.float { &float_pool } else { &int_pool };
        // Highest-register-first, matching the seed's pool.pop() bias.
        let fit = pool.iter().rev().copied().find(|r| {
            assigned_to
                .get(r)
                .map(|roots| {
                    roots
                        .iter()
                        .all(|&o| !ranges_overlap(&ranges[o], &ranges[iv.root]))
                })
                .unwrap_or(true)
        });
        if let Some(r) = fit {
            assignment.insert(iv.root, r);
            assigned_to.entry(r).or_default().push(iv.root);
            report.assigned += 1;
            continue;
        }
        // Under pressure: pick a victim to evict, or spill the current
        // interval. Only registers with exactly one conflicting holder
        // are eviction candidates (holes can pack several values into
        // one register; evicting a whole stack is never profitable).
        let mut best: Option<(u64, u32, usize)> = None; // (score, reg, victim)
        for &r in pool.iter().rev() {
            let conflicting: Vec<usize> = assigned_to
                .get(&r)
                .map(|roots| {
                    roots
                        .iter()
                        .copied()
                        .filter(|&o| ranges_overlap(&ranges[o], &ranges[iv.root]))
                        .collect()
                })
                .unwrap_or_default();
            if conflicting.len() != 1 {
                continue;
            }
            let victim = conflicting[0];
            let score = if opts.spill_next_use {
                next_use_after(victim, iv.start, false)
            } else {
                ranges[victim].last().unwrap().1 as u64
            };
            if best.map(|(s, _, _)| score > s).unwrap_or(true) {
                best = Some((score, r, victim));
            }
        }
        let cur_score = if opts.spill_next_use {
            next_use_after(iv.root, iv.start, true)
        } else {
            iv.end as u64
        };
        match best {
            Some((score, r, victim)) if score > cur_score => {
                assignment.remove(&victim);
                assigned_to.get_mut(&r).unwrap().retain(|&o| o != victim);
                spills.insert(victim, next_slot);
                next_slot += 1;
                report.spilled += 1;
                assignment.insert(iv.root, r);
                assigned_to.entry(r).or_default().push(iv.root);
                report.assigned += 1;
            }
            _ => {
                spills.insert(iv.root, next_slot);
                next_slot += 1;
                report.spilled += 1;
            }
        }
    }
    f.spill_size = next_slot * 4;

    // ---- Rewrite: apply assignments, insert spill loads/stores. ----
    let frame_base = f.frame_size; // spill slots sit above the allocas
    let root_of = {
        let mut memo = parent.clone();
        for v in 0..nv {
            let r = find(&mut memo, v);
            memo[v] = r;
        }
        memo
    };
    let spill_lw = |sc: u32, slot: u32| -> MInst {
        MInst {
            spill: true,
            ..MInst::rri(
                Op::LW,
                MReg(sc),
                MReg::phys(super::isa::SP),
                (frame_base + slot * 4) as i64,
            )
        }
    };
    for b in f.blocks.iter_mut() {
        let mut out: Vec<MInst> = Vec::with_capacity(b.insts.len());
        for inst in b.insts.drain(..) {
            let mut i = inst;
            let mut pre: Vec<MInst> = vec![];
            let mut post: Vec<MInst> = vec![];
            let map_use = |r: MReg, scratch: u32, pre: &mut Vec<MInst>| -> MReg {
                if !r.is_virt() {
                    return r;
                }
                let root = root_of[r.virt_idx()];
                if let Some(&p) = assignment.get(&root) {
                    return MReg(p);
                }
                let slot = spills[&root];
                pre.push(spill_lw(scratch, slot));
                MReg(scratch)
            };
            // rd-as-use ops (CMOV, AMOCAS) read rd too.
            let rd_is_use = matches!(i.op, Op::CMOV | Op::AMOCAS);
            if !i.rs1.is_none() {
                let sc = if i.rs1.is_virt() && f.vreg_float[i.rs1.virt_idx()] {
                    FT5
                } else {
                    T5
                };
                i.rs1 = map_use(i.rs1, sc, &mut pre);
            }
            if !i.rs2.is_none() {
                let sc = if i.rs2.is_virt() && f.vreg_float[i.rs2.virt_idx()] {
                    FT6
                } else {
                    T6
                };
                i.rs2 = map_use(i.rs2, sc, &mut pre);
            }
            if !i.rd.is_none() && i.rd.is_virt() {
                let r = i.rd;
                let root = root_of[r.virt_idx()];
                if let Some(&p) = assignment.get(&root) {
                    i.rd = MReg(p);
                } else {
                    let slot = spills[&root];
                    // rd shares the instruction with rs1/rs2 reloads when it
                    // is also a source (CMOV/AMOCAS): use a dedicated
                    // scratch so the pre-load cannot clobber them.
                    let sc = match (rd_is_use, f.vreg_float[r.virt_idx()]) {
                        (true, true) => FT7,
                        (true, false) => T7,
                        (false, true) => FT5,
                        (false, false) => T5,
                    };
                    if rd_is_use {
                        pre.push(spill_lw(sc, slot));
                    }
                    i.rd = MReg(sc);
                    if i.def().is_some() {
                        post.push(MInst {
                            op: Op::SW,
                            rd: super::mir::NONE,
                            rs1: MReg::phys(super::isa::SP),
                            rs2: MReg(sc),
                            imm: (frame_base + slot * 4) as i64,
                            spill: true,
                            ..MInst::new(Op::SW)
                        });
                    }
                }
            }
            out.extend(pre);
            out.push(i);
            out.extend(post);
        }
        b.insts = out;
    }
    report
}

/// Sort and merge touching/overlapping half-open ranges in place.
fn normalize(rs: &mut Vec<(u32, u32)>) {
    if rs.len() <= 1 {
        return;
    }
    rs.sort_unstable();
    let mut out: Vec<(u32, u32)> = Vec::with_capacity(rs.len());
    for &(s, e) in rs.iter() {
        match out.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => out.push((s, e)),
        }
    }
    *rs = out;
}

/// Any overlap between two normalized range sets?
fn ranges_overlap(a: &[(u32, u32)], b: &[(u32, u32)]) -> bool {
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        let (s1, e1) = a[i];
        let (s2, e2) = b[j];
        if s1 < e2 && s2 < e1 {
            return true;
        }
        if e1 <= e2 {
            i += 1;
        } else {
            j += 1;
        }
    }
    false
}

/// Insert prologue/epilogue once frame + spill sizes are final.
pub fn finalize_frame(f: &mut MFunction) {
    let ra_bytes = if f.has_calls { 4 } else { 0 };
    let total = (f.frame_size + f.spill_size + ra_bytes + 7) & !7;
    if total == 0 {
        return;
    }
    let sp = MReg::phys(super::isa::SP);
    let ra = MReg::phys(super::isa::RA);
    // Prologue at the very beginning.
    let mut pro = vec![MInst::rri(Op::ADDI, sp, sp, -(total as i64))];
    if f.has_calls {
        pro.push(MInst {
            op: Op::SW,
            rd: super::mir::NONE,
            rs1: sp,
            rs2: ra,
            imm: (total - 4) as i64,
            ..MInst::new(Op::SW)
        });
    }
    let entry = &mut f.blocks[0].insts;
    for (k, p) in pro.into_iter().enumerate() {
        entry.insert(k, p);
    }
    // Epilogue before every return (JALR x0, ra).
    for b in f.blocks.iter_mut() {
        let mut k = 0;
        while k < b.insts.len() {
            let is_ret = b.insts[k].op == Op::JALR
                && b.insts[k].rd == MReg::phys(0)
                && b.insts[k].callee.is_none();
            if is_ret {
                let mut epi = vec![];
                if f.has_calls {
                    epi.push(MInst::rri(Op::LW, ra, sp, (total - 4) as i64));
                }
                epi.push(MInst::rri(Op::ADDI, sp, sp, total as i64));
                for (j, e) in epi.into_iter().enumerate() {
                    b.insts.insert(k + j, e);
                    k += 1;
                }
            }
            k += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::mir::MBlock;

    fn func_with_pressure(n: usize) -> MFunction {
        // n live values summed at the end — forces spills for large n.
        let mut f = MFunction {
            name: "t".into(),
            blocks: vec![MBlock::default()],
            vreg_float: vec![],
            frame_size: 0,
            spill_size: 0,
            has_calls: false,
            local_mem_size: 0,
        };
        let regs: Vec<MReg> = (0..n).map(|_| f.new_vreg(false)).collect();
        for (k, &r) in regs.iter().enumerate() {
            f.blocks[0].insts.push(MInst::li(r, k as i64));
        }
        let acc = f.new_vreg(false);
        f.blocks[0].insts.push(MInst::li(acc, 0));
        for &r in &regs {
            f.blocks[0].insts.push(MInst::rrr(Op::ADD, acc, acc, r));
        }
        let mut ret = MInst::new(Op::JALR);
        ret.rd = MReg::phys(0);
        ret.rs1 = MReg::phys(super::super::isa::RA);
        f.blocks[0].insts.push(MInst::mv(MReg::phys(10), acc));
        f.blocks[0].insts.push(ret);
        f
    }

    fn assert_allocated(f: &MFunction) {
        for b in &f.blocks {
            for i in &b.insts {
                assert!(!i.rd.is_virt() && !i.rs1.is_virt() && !i.rs2.is_virt(), "{i:?}");
            }
        }
    }

    #[test]
    fn allocates_without_spills_when_fits() {
        let mut f = func_with_pressure(8);
        let rep = allocate(&mut f, &RegFile::vortex());
        assert_eq!(rep.spilled, 0);
        assert_allocated(&f);
    }

    #[test]
    fn spills_under_pressure() {
        let mut f = func_with_pressure(40);
        let rep = allocate(&mut f, &RegFile::vortex());
        assert!(rep.spilled > 0);
        assert!(f.spill_size >= 4 * rep.spilled as u32);
        assert_allocated(&f);
        // Spill traffic exists and is tagged.
        assert!(f.blocks[0].insts.iter().any(|i| i.op == Op::SW && i.spill));
        assert!(f.blocks[0].insts.iter().any(|i| i.op == Op::LW && i.spill));
    }

    /// The allocator pools come from the target's register-file shape: a
    /// narrower allocatable window spills where the full file does not.
    #[test]
    fn pools_follow_regfile_shape() {
        let narrow = RegFile {
            int_alloc: (5, 12),
            ..RegFile::vortex()
        };
        let mut f = func_with_pressure(12);
        let rep = allocate(&mut f, &narrow);
        assert!(rep.spilled > 0, "13 live values cannot fit 8 allocatable regs");
        assert_allocated(&f);
        let mut f2 = func_with_pressure(12);
        assert_eq!(allocate(&mut f2, &RegFile::vortex()).spilled, 0);
    }

    #[test]
    fn call_crossing_values_are_spilled() {
        let mut f = MFunction {
            name: "t".into(),
            blocks: vec![MBlock::default()],
            vreg_float: vec![],
            frame_size: 0,
            spill_size: 0,
            has_calls: true,
            local_mem_size: 0,
        };
        let v = f.new_vreg(false);
        f.blocks[0].insts.push(MInst::li(v, 42));
        let mut call = MInst::new(Op::JAL);
        call.rd = MReg::phys(super::super::isa::RA);
        call.callee = Some("g".into());
        f.blocks[0].insts.push(call);
        f.blocks[0].insts.push(MInst::mv(MReg::phys(10), v)); // use after call
        let mut ret = MInst::new(Op::JALR);
        ret.rd = MReg::phys(0);
        ret.rs1 = MReg::phys(super::super::isa::RA);
        f.blocks[0].insts.push(ret);
        let rep = allocate(&mut f, &RegFile::vortex());
        assert_eq!(rep.spilled, 1);
        finalize_frame(&mut f);
        // prologue adjusts sp and saves ra.
        assert_eq!(f.blocks[0].insts[0].op, Op::ADDI);
        assert!(f.blocks[0].insts[1].op == Op::SW);
    }

    /// Coalescing: a chain of phi-style copies collapses onto one
    /// physical register; `cleanup_identities` then removes the movs.
    #[test]
    fn coalesces_virtual_copies() {
        let mut f = MFunction {
            name: "t".into(),
            blocks: vec![MBlock::default()],
            vreg_float: vec![],
            frame_size: 0,
            spill_size: 0,
            has_calls: false,
            local_mem_size: 0,
        };
        let a = f.new_vreg(false);
        let b = f.new_vreg(false);
        f.blocks[0].insts.push(MInst::li(a, 3));
        f.blocks[0].insts.push(MInst::mv(b, a)); // a dead after this copy
        f.blocks[0].insts.push(MInst::rrr(Op::ADD, MReg::phys(10), b, b));
        let rep = allocate_with(&mut f, &RegFile::vortex(), RegAllocOptions::quality());
        assert_eq!(rep.coalesced, 1);
        let mv = f.blocks[0].insts.iter().find(|i| i.op == Op::MOV).unwrap();
        assert_eq!(mv.rd, mv.rs1, "coalesced copy must be an identity");
        let removed = crate::backend::combine::cleanup_identities(&mut f);
        assert_eq!(removed, 1);
        assert!(!f.blocks[0].insts.iter().any(|i| i.op == Op::MOV));
    }

    /// Coalescing must refuse when source and destination interfere
    /// (the source lives past the copy).
    #[test]
    fn coalescing_respects_interference() {
        let mut f = MFunction {
            name: "t".into(),
            blocks: vec![MBlock::default()],
            vreg_float: vec![],
            frame_size: 0,
            spill_size: 0,
            has_calls: false,
            local_mem_size: 0,
        };
        let a = f.new_vreg(false);
        let b = f.new_vreg(false);
        f.blocks[0].insts.push(MInst::li(a, 3));
        f.blocks[0].insts.push(MInst::mv(b, a));
        // b redefined while a still live -> they interfere.
        f.blocks[0].insts.push(MInst::rri(Op::ADDI, b, b, 1));
        f.blocks[0].insts.push(MInst::rrr(Op::ADD, MReg::phys(10), a, b));
        let rep = allocate_with(&mut f, &RegFile::vortex(), RegAllocOptions::quality());
        assert_eq!(rep.coalesced, 0);
        let mv = f.blocks[0].insts.iter().find(|i| i.op == Op::MOV).unwrap();
        assert_ne!(mv.rd, mv.rs1, "interfering copy must keep two registers");
    }

    /// Live-range holes: two values whose ranges do not overlap share
    /// one register under a one-register pool, with no spill.
    #[test]
    fn holes_allow_register_sharing() {
        let one_reg = RegFile {
            int_alloc: (5, 5),
            ..RegFile::vortex()
        };
        let build = || {
            let mut f = MFunction {
                name: "t".into(),
                blocks: vec![MBlock::default()],
                vreg_float: vec![],
                frame_size: 0,
                spill_size: 0,
                has_calls: false,
                local_mem_size: 0,
            };
            let a = f.new_vreg(false);
            let b = f.new_vreg(false);
            f.blocks[0].insts.push(MInst::li(a, 1));
            f.blocks[0].insts.push(MInst::mv(MReg::phys(10), a)); // a dies
            f.blocks[0].insts.push(MInst::li(b, 2));
            f.blocks[0].insts.push(MInst::mv(MReg::phys(11), b));
            f
        };
        let mut f = build();
        let rep = allocate_with(
            &mut f,
            &one_reg,
            RegAllocOptions {
                holes: true,
                ..Default::default()
            },
        );
        assert_eq!(rep.spilled, 0, "disjoint ranges share x5");
        assert_allocated(&f);
    }

    /// Belady spill choice: under a two-register pool, the value whose
    /// next use is furthest loses its register; the loop-carried
    /// accumulator pattern keeps its register and total spill traffic is
    /// no worse than the longest-interval heuristic.
    #[test]
    fn furthest_next_use_spills_cold_value() {
        let build = || {
            let mut f = MFunction {
                name: "t".into(),
                blocks: vec![MBlock::default()],
                vreg_float: vec![],
                frame_size: 0,
                spill_size: 0,
                has_calls: false,
                local_mem_size: 0,
            };
            // cold is defined first, used only at the very end; the
            // hot pair cycles in between.
            let cold = f.new_vreg(false);
            let h1 = f.new_vreg(false);
            let h2 = f.new_vreg(false);
            f.blocks[0].insts.push(MInst::li(cold, 9));
            f.blocks[0].insts.push(MInst::li(h1, 1));
            f.blocks[0].insts.push(MInst::li(h2, 2));
            for _ in 0..4 {
                f.blocks[0].insts.push(MInst::rrr(Op::ADD, h1, h1, h2));
                f.blocks[0].insts.push(MInst::rrr(Op::ADD, h2, h2, h1));
            }
            f.blocks[0].insts.push(MInst::rrr(Op::ADD, MReg::phys(10), h1, cold));
            f
        };
        let two_regs = RegFile {
            int_alloc: (5, 6),
            ..RegFile::vortex()
        };
        let mut f = build();
        let rep = allocate_with(
            &mut f,
            &two_regs,
            RegAllocOptions {
                spill_next_use: true,
                ..Default::default()
            },
        );
        assert_allocated(&f);
        assert_eq!(rep.spilled, 1, "only the cold value spills");
        // The hot accumulators keep registers: no spill reload inside
        // the add chain (the only tagged lw is the final cold reload).
        let reloads = f
            .blocks[0]
            .insts
            .iter()
            .filter(|i| i.op == Op::LW && i.spill)
            .count();
        assert_eq!(reloads, 1);
    }

    /// Spill-scratch collision (the satellite case): CMOV and AMOCAS
    /// with rs1, rs2 AND the read-modify-write destination all spilled
    /// must reload through three distinct scratches (T5/T6/T7) and
    /// store the result from the rd scratch.
    #[test]
    fn rmw_spill_scratches_never_alias() {
        for op in [Op::CMOV, Op::AMOCAS] {
            let mut f = MFunction {
                name: "t".into(),
                blocks: vec![MBlock::default()],
                vreg_float: vec![],
                frame_size: 0,
                spill_size: 0,
                has_calls: false,
                local_mem_size: 0,
            };
            // One allocatable register, pinned by `filler` (its next use
            // is always nearer than d/c/v's, so Belady never evicts it
            // and the CMOV/AMOCAS operands all spill).
            let no_regs = RegFile {
                int_alloc: (5, 5),
                ..RegFile::vortex()
            };
            let filler = f.new_vreg(false);
            let d = f.new_vreg(false);
            let c = f.new_vreg(false);
            let v = f.new_vreg(false);
            f.blocks[0].insts.push(MInst::li(filler, 0));
            f.blocks[0].insts.push(MInst::li(d, 1));
            f.blocks[0].insts.push(MInst::li(c, 2));
            f.blocks[0].insts.push(MInst::li(v, 3));
            f.blocks[0]
                .insts
                .push(MInst::rrr(Op::ADD, MReg::phys(12), filler, filler));
            f.blocks[0].insts.push(MInst::rrr(op, d, c, v));
            // Keep everything live past the op.
            f.blocks[0].insts.push(MInst::rrr(Op::ADD, MReg::phys(10), d, c));
            f.blocks[0].insts.push(MInst::rrr(Op::ADD, MReg::phys(11), v, filler));
            let rep = allocate_with(&mut f, &no_regs, RegAllocOptions::quality());
            assert!(rep.spilled >= 3, "{op:?}: want rs1/rs2/rd all spilled");
            let pos = f.blocks[0].insts.iter().position(|i| i.op == op).unwrap();
            let i = &f.blocks[0].insts[pos];
            assert_eq!(i.rs1, MReg(T5), "{op:?} rs1 reload scratch");
            assert_eq!(i.rs2, MReg(T6), "{op:?} rs2 reload scratch");
            assert_eq!(i.rd, MReg(T7), "{op:?} rmw destination scratch");
            // The three pre-loads hit three distinct scratches...
            let pre: Vec<&MInst> = f.blocks[0].insts[pos.saturating_sub(3)..pos].iter().collect();
            assert_eq!(pre.len(), 3);
            assert!(pre.iter().all(|p| p.op == Op::LW && p.spill));
            let mut scratches: Vec<u32> = pre.iter().map(|p| p.rd.0).collect();
            scratches.sort_unstable();
            assert_eq!(scratches, vec![T7, T5, T6], "{op:?} scratch set");
            // ...and the post-store writes back from the rd scratch.
            let post = &f.blocks[0].insts[pos + 1];
            assert!(post.op == Op::SW && post.spill);
            assert_eq!(post.rs2, MReg(T7));
        }
    }

    /// Quality mode never leaves a virtual register behind on a
    /// multi-block CFG with a loop (ranges across back edges).
    #[test]
    fn quality_mode_handles_loops() {
        let mut f = MFunction {
            name: "t".into(),
            blocks: vec![MBlock::default(), MBlock::default(), MBlock::default()],
            vreg_float: vec![],
            frame_size: 0,
            spill_size: 0,
            has_calls: false,
            local_mem_size: 0,
        };
        let v0 = f.new_vreg(false);
        let v1 = f.new_vreg(false);
        f.blocks[0].insts.push(MInst::li(v0, 3));
        let mut j = MInst::new(Op::J);
        j.t1 = Some(1);
        f.blocks[0].insts.push(j);
        f.blocks[1].insts.push(MInst::rrr(Op::ADD, v1, v0, v0));
        let mut bnez = MInst {
            rs1: v1,
            ..MInst::new(Op::BNEZ)
        };
        bnez.t1 = Some(1);
        f.blocks[1].insts.push(bnez);
        let mut j2 = MInst::new(Op::J);
        j2.t1 = Some(2);
        f.blocks[1].insts.push(j2);
        f.blocks[2].insts.push(MInst::mv(MReg::phys(10), v0));
        f.blocks[2].insts.push(MInst::new(Op::ECALL));
        let rep = allocate_with(&mut f, &RegFile::vortex(), RegAllocOptions::quality());
        assert_eq!(rep.spilled, 0);
        assert_allocated(&f);
        // v0 is live around the loop: v1's register must differ.
        let add = f.blocks[1].insts.iter().find(|i| i.op == Op::ADD).unwrap();
        assert_ne!(add.rd, add.rs1, "loop-live value must not be clobbered");
    }
}
