//! The divergence **safety net** — paper §4.3 / Fig. 5.
//!
//! VOLT plans divergence at the IR level; late machine passes can still
//! break the invariants. This "lightweight MIR safety net", run as the
//! *last* machine pass after register allocation, repairs or rejects:
//!
//! * **(a) branch reordering** — the layout pass may swap a split's arms
//!   for fallthrough without updating the predicate sense; the split's
//!   `swapped` marker is consumed here by flipping `vx_split` ↔
//!   `vx_split.n` so lane semantics align.
//! * **(b) predicate drift** — spill rematerialization may re-derive the
//!   branch predicate into a different register than the one `vx_split`
//!   reads. The net *unifies* split and predicate by checking the
//!   reaching definition inside the block and, when the defining compare's
//!   operands are still intact, re-materializing the compare immediately
//!   before the split (back-to-back, as the paper describes).
//! * **(c) divergent select** — when ZiCond is off the IR contract says no
//!   `select` survives to isel; any `vx_cmov` found is an error.
//!
//! It finally *verifies* split/join pairing: every split's reconvergence
//! block must begin with `vx_join`, and every `vx_pred` exit must be a
//! block whose live mask was saved (structural check: the pred's mask
//! operand must be a `vx_active_threads` result — tracked by the emitter's
//! metadata in debug builds; here we check the join pairing, the part that
//! is statically decidable).

use super::isa::Op;
use super::mir::MFunction;

#[derive(Debug, Default)]
pub struct SafetyNetReport {
    pub negations_fixed: usize,
    pub predicates_rematerialized: usize,
    pub errors: Vec<String>,
}

pub fn run(f: &mut MFunction, zicond: bool) -> SafetyNetReport {
    let mut rep = SafetyNetReport::default();
    fix_inverted_splits(f, &mut rep);
    unify_split_predicates(f, &mut rep);
    if !zicond {
        for b in &f.blocks {
            for i in &b.insts {
                if i.op == Op::CMOV {
                    rep.errors.push(
                        "divergent select reached the back-end without ZiCond (Fig. 5c)".into(),
                    );
                }
            }
        }
    }
    verify_pairing(f, &mut rep);
    rep
}

/// (a) Swapped split arms: flip the negate sense.
fn fix_inverted_splits(f: &mut MFunction, rep: &mut SafetyNetReport) {
    for b in f.blocks.iter_mut() {
        for i in b.insts.iter_mut() {
            if matches!(i.op, Op::SPLIT | Op::SPLITN) && i.swapped {
                i.op = if i.op == Op::SPLIT {
                    Op::SPLITN
                } else {
                    Op::SPLIT
                };
                i.swapped = false;
                rep.negations_fixed += 1;
            }
        }
    }
}

/// (b) Predicate drift: the register a split reads must hold the value of
/// the predicate-defining instruction at the split. Scan backwards from
/// the split; if the register is clobbered between its defining compare
/// and the split, re-materialize the compare right before the split.
fn unify_split_predicates(f: &mut MFunction, rep: &mut SafetyNetReport) {
    for bi in 0..f.blocks.len() {
        let split_pos: Vec<usize> = f.blocks[bi]
            .insts
            .iter()
            .enumerate()
            .filter(|(_, i)| matches!(i.op, Op::SPLIT | Op::SPLITN))
            .map(|(k, _)| k)
            .collect();
        for sp in split_pos {
            let pred = f.blocks[bi].insts[sp].rs1;
            // Find the last def of `pred` before the split in this block.
            let mut def_idx: Option<usize> = None;
            for k in (0..sp).rev() {
                if f.blocks[bi].insts[k].def() == Some(pred) {
                    def_idx = Some(k);
                    break;
                }
            }
            let Some(di) = def_idx else { continue };
            let def = f.blocks[bi].insts[di].clone();
            // A legitimate split predicate is produced by a compare/logical
            // op or a spill reload (MOV / LW). Anything else means the
            // register was clobbered after the real predicate definition —
            // the Fig. 5(b) drift. Repair: find the most recent
            // boolean-producing def of the same register and re-materialize
            // it immediately before the split ("back-to-back").
            if is_bool_producer(def.op) {
                continue;
            }
            let remat_src = (0..di).rev().find(|&k| {
                let i2 = &f.blocks[bi].insts[k];
                i2.def() == Some(pred) && is_bool_producer(i2.op) && is_rematerializable(i2.op)
            });
            match remat_src {
                Some(k) => {
                    let cand = f.blocks[bi].insts[k].clone();
                    // Sources must not be redefined between the compare and
                    // the split.
                    let sources_ok = cand.uses().iter().all(|s| {
                        !f.blocks[bi].insts[k + 1..sp]
                            .iter()
                            .any(|i2| i2.def() == Some(*s))
                    });
                    if sources_ok {
                        let mut remat = cand;
                        remat.rd = pred;
                        f.blocks[bi].insts.insert(sp, remat);
                        rep.predicates_rematerialized += 1;
                    } else {
                        rep.errors.push(format!(
                            "predicate drift at split in block {bi}: compare sources clobbered"
                        ));
                    }
                }
                None => rep.errors.push(format!(
                    "predicate drift at split in block {bi}: no reaching compare"
                )),
            }
        }
    }
}

/// Ops that legitimately produce a split predicate.
fn is_bool_producer(op: Op) -> bool {
    matches!(
        op,
        Op::SEQ
            | Op::SNE
            | Op::SLT
            | Op::SLE
            | Op::SLTU
            | Op::SGEU
            | Op::FEQ
            | Op::FNE
            | Op::FLT
            | Op::FLE
            | Op::FGT
            | Op::FGE
            | Op::AND
            | Op::OR
            | Op::XOR
            | Op::XORI
            | Op::ANDI
            | Op::ORI
            | Op::MOV
            | Op::LW
            | Op::VOTEALL
            | Op::VOTEANY
            | Op::CMOV
    )
}

fn is_rematerializable(op: Op) -> bool {
    matches!(
        op,
        Op::SEQ
            | Op::SNE
            | Op::SLT
            | Op::SLE
            | Op::SLTU
            | Op::SGEU
            | Op::FEQ
            | Op::FNE
            | Op::FLT
            | Op::FLE
            | Op::FGT
            | Op::FGE
            | Op::AND
            | Op::OR
            | Op::XOR
            | Op::XORI
            | Op::ANDI
            | Op::ORI
            | Op::LI
            | Op::MOV
            | Op::LW
    )
}

/// Split/join pairing: the reconvergence block of every split must start
/// with `vx_join` (phis are already destructed at this stage, so the join
/// must be the literal first instruction).
fn verify_pairing(f: &MFunction, rep: &mut SafetyNetReport) {
    for b in &f.blocks {
        for i in &b.insts {
            if matches!(i.op, Op::SPLIT | Op::SPLITN) {
                let Some(j) = i.tjoin else {
                    rep.errors.push("split without reconvergence block".into());
                    continue;
                };
                let ok = f.blocks[j]
                    .insts
                    .iter()
                    .any(|x| x.op == Op::JOIN);
                if !ok {
                    rep.errors
                        .push(format!("split reconvergence block {j} has no vx_join"));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::mir::{MBlock, MFunction, MInst, MReg};

    fn base_func() -> MFunction {
        MFunction {
            name: "t".into(),
            blocks: vec![MBlock::default(), MBlock::default(), MBlock::default()],
            vreg_float: vec![],
            frame_size: 0,
            spill_size: 0,
            has_calls: false,
            local_mem_size: 0,
        }
    }

    #[test]
    fn fixes_swapped_split() {
        let mut f = base_func();
        let mut s = MInst::new(Op::SPLIT);
        s.rs1 = MReg::phys(5);
        s.t1 = Some(1);
        s.t2 = Some(2);
        s.tjoin = Some(2);
        s.swapped = true;
        f.blocks[0].insts.push(s);
        f.blocks[2].insts.push(MInst::new(Op::JOIN));
        let rep = run(&mut f, true);
        assert_eq!(rep.negations_fixed, 1);
        assert_eq!(f.blocks[0].insts[0].op, Op::SPLITN);
        assert!(!f.blocks[0].insts[0].swapped);
        assert!(rep.errors.is_empty());
    }

    #[test]
    fn rematerializes_drifted_predicate() {
        // slt x5, x6, x7 ; li x5, 0 (clobber — injected drift) ; split x5
        let mut f = base_func();
        f.blocks[0].insts.push(MInst::rrr(
            Op::SLT,
            MReg::phys(5),
            MReg::phys(6),
            MReg::phys(7),
        ));
        f.blocks[0].insts.push(MInst::li(MReg::phys(5), 0));
        let mut s = MInst::new(Op::SPLIT);
        s.rs1 = MReg::phys(5);
        s.t1 = Some(1);
        s.t2 = Some(2);
        s.tjoin = Some(2);
        f.blocks[0].insts.push(s);
        f.blocks[2].insts.push(MInst::new(Op::JOIN));
        let rep = run(&mut f, true);
        assert_eq!(rep.predicates_rematerialized, 1);
        // The rematerialized compare sits immediately before the split.
        let n = f.blocks[0].insts.len();
        assert_eq!(f.blocks[0].insts[n - 2].op, Op::SLT);
        assert!(matches!(f.blocks[0].insts[n - 1].op, Op::SPLIT));
        assert!(rep.errors.is_empty());
    }

    #[test]
    fn detects_missing_join() {
        let mut f = base_func();
        let mut s = MInst::new(Op::SPLIT);
        s.rs1 = MReg::phys(5);
        s.t1 = Some(1);
        s.t2 = Some(2);
        s.tjoin = Some(2); // block 2 has no JOIN
        f.blocks[0].insts.push(s);
        let rep = run(&mut f, true);
        assert!(!rep.errors.is_empty());
    }

    #[test]
    fn rejects_cmov_without_zicond() {
        let mut f = base_func();
        f.blocks[0].insts.push(MInst::rrr(
            Op::CMOV,
            MReg::phys(5),
            MReg::phys(6),
            MReg::phys(7),
        ));
        let rep = run(&mut f, false);
        assert!(!rep.errors.is_empty());
        let rep2 = run(&mut f, true);
        assert!(rep2.errors.is_empty());
    }
}
