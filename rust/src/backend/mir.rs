//! Machine IR: virtual-register instructions over the target ISA, one
//! MBlock per IR block, with explicit branch-target block indices that the
//! emitter later resolves to instruction addresses.

use super::isa::{is_float_reg, Op};
use crate::ir::Loc;

/// Machine register: `< 64` = physical (x0..x31, f0..f31), `>= 64` virtual.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct MReg(pub u32);

pub const NONE: MReg = MReg(u32::MAX);

impl MReg {
    pub fn phys(r: u8) -> MReg {
        MReg(r as u32)
    }
    pub fn is_phys(self) -> bool {
        self.0 < 64
    }
    pub fn is_virt(self) -> bool {
        self.0 >= 64 && self != NONE
    }
    pub fn is_none(self) -> bool {
        self == NONE
    }
    pub fn virt_idx(self) -> usize {
        (self.0 - 64) as usize
    }
}

#[derive(Clone, Debug)]
pub struct MInst {
    pub op: Op,
    pub rd: MReg,
    pub rs1: MReg,
    pub rs2: MReg,
    pub imm: i64,
    /// Primary branch target (then / body / jump).
    pub t1: Option<usize>,
    /// Secondary target (split else / pred exit / condbr fallthrough jump).
    pub t2: Option<usize>,
    /// Split reconvergence block.
    pub tjoin: Option<usize>,
    /// Call target function name (JAL).
    pub callee: Option<String>,
    /// Layout swapped split arms without fixing negation — the Fig. 5(a)
    /// hazard marker the safety net repairs.
    pub swapped: bool,
    /// Source location inherited from the IR instruction this was
    /// selected from (`None` for selection/regalloc-synthesized code;
    /// the emitter's line-table fill resolves those to the nearest
    /// located neighbour).
    pub loc: Option<Loc>,
    /// Spill traffic inserted by the register allocator (reload `lw` /
    /// store `sw` through the scratch registers). Carried into
    /// [`crate::backend::emit::ProgramImage::pc_spill`] so the profiler
    /// can attribute spill cycles per source line.
    pub spill: bool,
}

impl MInst {
    pub fn new(op: Op) -> MInst {
        MInst {
            op,
            rd: NONE,
            rs1: NONE,
            rs2: NONE,
            imm: 0,
            t1: None,
            t2: None,
            tjoin: None,
            callee: None,
            swapped: false,
            loc: None,
            spill: false,
        }
    }
    pub fn rrr(op: Op, rd: MReg, rs1: MReg, rs2: MReg) -> MInst {
        MInst {
            rd,
            rs1,
            rs2,
            ..MInst::new(op)
        }
    }
    pub fn rri(op: Op, rd: MReg, rs1: MReg, imm: i64) -> MInst {
        MInst {
            rd,
            rs1,
            imm,
            ..MInst::new(op)
        }
    }
    pub fn li(rd: MReg, imm: i64) -> MInst {
        MInst {
            rd,
            imm,
            ..MInst::new(Op::LI)
        }
    }
    pub fn mv(rd: MReg, rs1: MReg) -> MInst {
        MInst {
            rd,
            rs1,
            ..MInst::new(Op::MOV)
        }
    }

    /// Registers read by this instruction.
    pub fn uses(&self) -> Vec<MReg> {
        let mut v = vec![];
        match self.op {
            // rd is also a source for conditional-move and CAS.
            Op::CMOV | Op::AMOCAS => {
                if !self.rd.is_none() {
                    v.push(self.rd);
                }
            }
            _ => {}
        }
        if !self.rs1.is_none() {
            v.push(self.rs1);
        }
        if !self.rs2.is_none() {
            v.push(self.rs2);
        }
        v
    }

    /// Register written (if any).
    pub fn def(&self) -> Option<MReg> {
        if self.rd.is_none() {
            None
        } else {
            match self.op {
                Op::SW | Op::BAR | Op::TMC | Op::PRED | Op::SPLIT | Op::SPLITN | Op::PRINTI
                | Op::PRINTF | Op::WSPAWN => None,
                _ => Some(self.rd),
            }
        }
    }

    pub fn is_terminator(&self) -> bool {
        matches!(
            self.op,
            Op::J | Op::JALR | Op::ECALL | Op::SPLIT | Op::SPLITN | Op::PRED
        ) && self.callee.is_none()
    }

    pub fn is_call(&self) -> bool {
        self.op == Op::JAL && self.callee.is_some()
    }
}

#[derive(Clone, Debug, Default)]
pub struct MBlock {
    pub insts: Vec<MInst>,
    pub name: String,
}

impl MBlock {
    /// Successor block indices (for liveness / layout).
    pub fn succs(&self) -> Vec<usize> {
        let mut out = vec![];
        for i in &self.insts {
            if i.is_call() {
                continue;
            }
            match i.op {
                Op::J | Op::BEQZ | Op::BNEZ => {
                    if let Some(t) = i.t1 {
                        out.push(t);
                    }
                }
                Op::SPLIT | Op::SPLITN | Op::PRED => {
                    if let Some(t) = i.t1 {
                        out.push(t);
                    }
                    if let Some(t) = i.t2 {
                        out.push(t);
                    }
                }
                _ => {}
            }
        }
        out.dedup();
        out
    }
}

#[derive(Clone, Debug)]
pub struct MFunction {
    pub name: String,
    pub blocks: Vec<MBlock>,
    /// Virtual register count and classes (true = float).
    pub vreg_float: Vec<bool>,
    /// Bytes of alloca frame space (before spills).
    pub frame_size: u32,
    /// Extra spill bytes (filled by regalloc).
    pub spill_size: u32,
    /// Does this function contain calls (needs ra save)?
    pub has_calls: bool,
    /// Shared-memory bytes required (from IR).
    pub local_mem_size: u32,
}

impl MFunction {
    pub fn new_vreg(&mut self, float: bool) -> MReg {
        self.vreg_float.push(float);
        MReg(64 + self.vreg_float.len() as u32 - 1)
    }
    pub fn is_float(&self, r: MReg) -> bool {
        if r.is_virt() {
            self.vreg_float[r.virt_idx()]
        } else {
            is_float_reg(r.0 as u8)
        }
    }
    pub fn num_insts(&self) -> usize {
        self.blocks.iter().map(|b| b.insts.len()).sum()
    }
}

/// Per-block liveness (backward dataflow over vregs only).
pub fn liveness(f: &MFunction) -> (Vec<std::collections::HashSet<MReg>>, Vec<std::collections::HashSet<MReg>>) {
    let n = f.blocks.len();
    let mut live_in: Vec<std::collections::HashSet<MReg>> = vec![Default::default(); n];
    let mut live_out: Vec<std::collections::HashSet<MReg>> = vec![Default::default(); n];
    let mut changed = true;
    while changed {
        changed = false;
        for b in (0..n).rev() {
            let mut out: std::collections::HashSet<MReg> = Default::default();
            for s in f.blocks[b].succs() {
                out.extend(live_in[s].iter().copied());
            }
            let mut inn = out.clone();
            for i in f.blocks[b].insts.iter().rev() {
                if let Some(d) = i.def() {
                    if d.is_virt() {
                        inn.remove(&d);
                    }
                }
                for u in i.uses() {
                    if u.is_virt() {
                        inn.insert(u);
                    }
                }
            }
            if out != live_out[b] || inn != live_in[b] {
                live_out[b] = out;
                live_in[b] = inn;
                changed = true;
            }
        }
    }
    (live_in, live_out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uses_defs() {
        let add = MInst::rrr(Op::ADD, MReg(64), MReg(65), MReg(66));
        assert_eq!(add.def(), Some(MReg(64)));
        assert_eq!(add.uses(), vec![MReg(65), MReg(66)]);
        let sw = MInst {
            rs1: MReg(64),
            rs2: MReg(65),
            rd: NONE,
            ..MInst::new(Op::SW)
        };
        assert_eq!(sw.def(), None);
        let cmov = MInst::rrr(Op::CMOV, MReg(64), MReg(65), MReg(66));
        assert!(cmov.uses().contains(&MReg(64)));
    }

    #[test]
    fn block_succs() {
        let mut b = MBlock::default();
        let mut bnez = MInst::new(Op::BNEZ);
        bnez.t1 = Some(2);
        b.insts.push(bnez);
        let mut j = MInst::new(Op::J);
        j.t1 = Some(3);
        b.insts.push(j);
        assert_eq!(b.succs(), vec![2, 3]);
    }

    #[test]
    fn liveness_simple_loop() {
        // b0: v0 = li; j b1   b1: v1 = add v0, v0; bnez v1 -> b1; j b2  b2: ecall
        let mut f = MFunction {
            name: "t".into(),
            blocks: vec![MBlock::default(), MBlock::default(), MBlock::default()],
            vreg_float: vec![false, false],
            frame_size: 0,
            spill_size: 0,
            has_calls: false,
            local_mem_size: 0,
        };
        let v0 = MReg(64);
        let v1 = MReg(65);
        f.blocks[0].insts.push(MInst::li(v0, 3));
        let mut j = MInst::new(Op::J);
        j.t1 = Some(1);
        f.blocks[0].insts.push(j);
        f.blocks[1].insts.push(MInst::rrr(Op::ADD, v1, v0, v0));
        let mut bnez = MInst {
            rs1: v1,
            ..MInst::new(Op::BNEZ)
        };
        bnez.t1 = Some(1);
        f.blocks[1].insts.push(bnez);
        let mut j2 = MInst::new(Op::J);
        j2.t1 = Some(2);
        f.blocks[1].insts.push(j2);
        f.blocks[2].insts.push(MInst::new(Op::ECALL));
        let (live_in, live_out) = liveness(&f);
        assert!(live_in[1].contains(&v0));
        assert!(live_out[0].contains(&v0));
        assert!(live_out[1].contains(&v0)); // loop back edge keeps v0 live
        assert!(!live_in[2].contains(&v0));
    }
}
