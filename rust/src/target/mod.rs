//! `volt::target` — the target-description layer (paper §5.3 / §6.1).
//!
//! The paper's extensibility claim ("easily adapted to emerging open-GPU
//! variants") needs every layer to consult *one* description of the
//! machine instead of hardcoding the evaluation Vortex. [`TargetDesc`]
//! centralizes all target knowledge:
//!
//! * [`Features`] — which ISA extensions exist (`vx_cmov`/ZiCond,
//!   `vx_shfl`, `vx_vote.*`, the FPU). The middle-end derives select
//!   legality from this set, instruction selection refuses extension ops
//!   the target lacks with a typed [`crate::backend::BackendError`], and
//!   the simulator traps on feature-gated opcodes it did not declare —
//!   so a miscompile for the wrong target is a loud error, never a
//!   silently wrong answer.
//! * [`WarpCaps`] — capability ceilings on the device geometry
//!   (threads/warp, warps/core, cores). [`crate::driver::VoltOptions`]
//!   validates the configured [`crate::sim::SimConfig`] against these at
//!   build time with typed `InvalidOptions` errors.
//! * [`RegFile`] — register-file shape; the linear-scan allocator builds
//!   its pools from it instead of hardcoded ranges.
//! * [`AddressMap`] — the device memory map previously frozen as
//!   constants in `backend/emit.rs`; the emitter lays out images and the
//!   simulator decodes address spaces from the same map.
//! * [`CostModel`] — per-functional-class issue costs driving the
//!   simulator timing model.
//!
//! A `TargetDesc` also *owns* its divergence seeds: it implements
//! [`TargetDivergenceInfo`], so `run_middle_end_with(m, cfg, &target)`
//! uses the target's own uniformity model (paper §4.3.1).
//!
//! Two built-in profiles ship: [`TargetDesc::vortex`] (the paper's
//! evaluation machine) and [`TargetDesc::vortex_min`] (a cut-down variant
//! with no ZiCond/shfl/vote extensions, a half-size warp table, two
//! cores, and no L2) — see `docs/TARGETS.md` for how to add more.

use crate::analysis::tti::{TargetDivergenceInfo, VortexTti};
use crate::analysis::UniformityOptions;
use crate::backend::isa::{Op, OpClass};
use crate::ir::{Function, InstData};

/// ISA-extension feature set (the §5.3 case-study axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Features {
    /// `vx_cmov` (ZiCond conditional move): divergent selects stay flat.
    pub zicond: bool,
    /// `vx_shfl`: cross-lane register reads.
    pub shfl: bool,
    /// `vx_vote.all` / `vx_vote.any` / `vx_vote.ballot`.
    pub vote: bool,
    /// Single-precision FPU (FADD..FSQRT plus the SFU transcendentals).
    pub fp: bool,
}

impl Features {
    /// Everything the evaluation Vortex implements.
    pub const fn vortex() -> Features {
        Features {
            zicond: true,
            shfl: true,
            vote: true,
            fp: true,
        }
    }

    /// Base machine only: no case-study extensions (FPU retained).
    pub const fn minimal() -> Features {
        Features {
            zicond: false,
            shfl: false,
            vote: false,
            fp: true,
        }
    }

    /// Stable bit encoding for cache fingerprints.
    pub fn bits(&self) -> u8 {
        (self.zicond as u8)
            | ((self.shfl as u8) << 1)
            | ((self.vote as u8) << 2)
            | ((self.fp as u8) << 3)
    }

    /// Whether this feature set implements `op`. Base-ISA ops are always
    /// supported; extension ops and FPU classes are gated.
    pub fn supports_op(&self, op: Op) -> bool {
        match op {
            Op::CMOV => self.zicond,
            Op::SHFL => self.shfl,
            Op::VOTEALL | Op::VOTEANY | Op::BALLOT => self.vote,
            _ => match op.class() {
                OpClass::Fpu | OpClass::FDiv | OpClass::Sfu => self.fp,
                _ => true,
            },
        }
    }

    /// Human-readable name of the feature gating `op` (diagnostics).
    pub fn gate_name(op: Op) -> Option<&'static str> {
        match op {
            Op::CMOV => Some("zicond"),
            Op::SHFL => Some("shfl"),
            Op::VOTEALL | Op::VOTEANY | Op::BALLOT => Some("vote"),
            _ => match op.class() {
                OpClass::Fpu | OpClass::FDiv | OpClass::Sfu => Some("fp"),
                _ => None,
            },
        }
    }
}

impl Default for Features {
    fn default() -> Features {
        Features::vortex()
    }
}

/// Capability ceilings on device geometry. The configured
/// [`crate::sim::SimConfig`] must sit at or below these; the options
/// layer enforces it with typed errors (no silent clamping).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WarpCaps {
    /// ≤ 32: the divergence/thread masks are 32-bit.
    pub max_threads_per_warp: u32,
    /// ≤ 32: the barrier arrival table is a 32-bit warp bitmask.
    pub max_warps_per_core: u32,
    pub max_cores: u32,
}

/// Register-file shape. Indices 0..`num_int` are integer (x0 hardwired
/// zero), `float_base`..`float_base+num_float` are floats. The allocator
/// derives its pools from the allocatable windows; the top three
/// registers of each bank are reserved spill scratch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RegFile {
    pub num_int: u8,
    pub num_float: u8,
    pub float_base: u8,
    /// First/last allocatable integer register (inclusive).
    pub int_alloc: (u8, u8),
    /// First/last allocatable float register (inclusive).
    pub float_alloc: (u8, u8),
    /// ABI argument window (excluded from pools in functions with calls).
    pub arg_base: u8,
    pub arg_count: u8,
}

impl RegFile {
    pub const fn vortex() -> RegFile {
        RegFile {
            num_int: 32,
            num_float: 32,
            float_base: 32,
            int_alloc: (5, 28),
            float_alloc: (32, 60),
            arg_base: 10,
            arg_count: 8,
        }
    }

    /// Structural validation against the machine's fixed register
    /// encoding and reserved set. The 64-bit instruction encoding pins
    /// the banks (x0..x31 integer, f0..f31 at `float_base` 32; see
    /// `backend/isa.rs::is_float_reg`), x0/ra/sp are special, and
    /// x29–x31 / f61–f63 are the allocator's spill scratch — an
    /// allocatable window that overlaps any of those would let the
    /// spill/reload path silently clobber live values, exactly the
    /// silent-miscompile class this layer exists to eliminate.
    /// [`crate::driver::VoltOptions::validate`] enforces this for every
    /// custom target.
    pub fn validate(&self) -> Result<(), String> {
        if self.num_int != 32 || self.num_float != 32 || self.float_base != 32 {
            return Err(format!(
                "register file shape {}i+{}f@{} is unsupported: the instruction \
                 encoding pins 32 integer + 32 float registers at float_base 32",
                self.num_int, self.num_float, self.float_base
            ));
        }
        let (ilo, ihi) = self.int_alloc;
        if ilo < 3 || ihi > 28 || ilo > ihi {
            return Err(format!(
                "int_alloc ({ilo}, {ihi}) must lie within x3..=x28 (x0/ra/sp are \
                 special, x29-x31 are spill scratch)"
            ));
        }
        let (flo, fhi) = self.float_alloc;
        if flo < 32 || fhi > 60 || flo > fhi {
            return Err(format!(
                "float_alloc ({flo}, {fhi}) must lie within f0..=f28 (register \
                 indices 32..=60; f61-f63 are spill scratch)"
            ));
        }
        if self.arg_base as u32 + self.arg_count as u32 > 32 {
            return Err(format!(
                "ABI argument window ({}, +{}) exceeds the register bank",
                self.arg_base, self.arg_count
            ));
        }
        Ok(())
    }
}

/// The device memory map (previously `backend/emit.rs` constants).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AddressMap {
    pub data_base: u32,
    pub local_base: u32,
    pub stack_base: u32,
    pub stack_size: u32,
    pub heap_base: u32,
}

impl AddressMap {
    pub const fn vortex() -> AddressMap {
        AddressMap {
            data_base: 0x0001_0000,
            local_base: 0x1000_0000,
            stack_base: 0x2000_0000,
            stack_size: 0x1000,
            heap_base: 0x4000_0000,
        }
    }
}

impl Default for AddressMap {
    fn default() -> AddressMap {
        AddressMap::vortex()
    }
}

/// Per-functional-class issue costs (cycles until the issuing warp is
/// ready again). Memory is a floor — the cache hierarchy adds latency.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CostModel {
    pub alu: u32,
    pub mul: u32,
    pub div: u32,
    pub fpu: u32,
    pub fdiv: u32,
    pub sfu: u32,
    pub mem_min: u32,
    pub branch: u32,
    pub vx: u32,
    pub sys: u32,
}

impl CostModel {
    pub const fn vortex() -> CostModel {
        CostModel {
            alu: 1,
            mul: 3,
            div: 16,
            fpu: 4,
            fdiv: 16,
            sfu: 8,
            mem_min: 1,
            branch: 1,
            vx: 2,
            sys: 1,
        }
    }

    pub fn issue_cost(&self, class: OpClass) -> u64 {
        (match class {
            OpClass::Alu => self.alu,
            OpClass::Mul => self.mul,
            OpClass::Div => self.div,
            OpClass::Fpu => self.fpu,
            OpClass::FDiv => self.fdiv,
            OpClass::Sfu => self.sfu,
            OpClass::Mem => self.mem_min,
            OpClass::Branch => self.branch,
            OpClass::Vx => self.vx,
            OpClass::Sys => self.sys,
        }) as u64
    }
}

impl Default for CostModel {
    fn default() -> CostModel {
        CostModel::vortex()
    }
}

/// Everything the stack knows about one machine. `Copy` so it can ride
/// inside [`crate::driver::VoltOptions`]; custom targets are plain
/// `const`-constructible literals (see `docs/TARGETS.md`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TargetDesc {
    pub name: &'static str,
    pub features: Features,
    pub caps: WarpCaps,
    pub regfile: RegFile,
    pub addr_map: AddressMap,
    pub costs: CostModel,
    /// Default device geometry ([`crate::sim::SimConfig::from_target`]).
    pub default_cores: u32,
    pub default_warps_per_core: u32,
    pub default_threads_per_warp: u32,
    /// Whether the default configuration has an L2.
    pub default_l2: bool,
}

impl TargetDesc {
    /// The paper's evaluation machine (§5): full extension set,
    /// 4 cores × 16 warps × 32 threads, L2 enabled.
    pub const fn vortex() -> TargetDesc {
        TargetDesc {
            name: "vortex",
            features: Features::vortex(),
            caps: WarpCaps {
                max_threads_per_warp: 32,
                max_warps_per_core: 32,
                max_cores: 64,
            },
            regfile: RegFile::vortex(),
            addr_map: AddressMap::vortex(),
            costs: CostModel::vortex(),
            default_cores: 4,
            default_warps_per_core: 16,
            default_threads_per_warp: 32,
            default_l2: true,
        }
    }

    /// A cut-down Vortex variant: no ZiCond/shfl/vote extensions, a
    /// half-size warp table, two cores, no L2. Warp *width* stays 32 —
    /// the VCL warp contract (`warpSize == 32`) is baked into CUDA-dialect
    /// kernels and the software warp-emulation scratch, so narrowing the
    /// machine means fewer warps and cores, not narrower warps. Selects
    /// are legalized to branches for this profile (no `vx_cmov` in its
    /// images) and warp builtins must use the software emulation
    /// (`warp_hw = false`); hardware shfl/vote requests fail with a typed
    /// back-end error.
    pub const fn vortex_min() -> TargetDesc {
        TargetDesc {
            name: "vortex-min",
            features: Features::minimal(),
            caps: WarpCaps {
                max_threads_per_warp: 32,
                max_warps_per_core: 8,
                max_cores: 2,
            },
            regfile: RegFile::vortex(),
            addr_map: AddressMap::vortex(),
            costs: CostModel::vortex(),
            default_cores: 2,
            default_warps_per_core: 8,
            default_threads_per_warp: 32,
            default_l2: false,
        }
    }

    /// Names of the built-in profiles, in presentation order (kept in
    /// lock-step with [`TargetDesc::builtins`] by a unit test; the
    /// registration point for a new profile is `builtins()`).
    pub const BUILTIN_NAMES: [&'static str; 2] = ["vortex", "vortex-min"];

    /// The built-in profiles themselves — the single registration point
    /// for new profiles (`by_name` and the name list derive from it).
    pub fn builtins() -> Vec<TargetDesc> {
        vec![TargetDesc::vortex(), TargetDesc::vortex_min()]
    }

    /// Look up a built-in profile by name (`_` and `-` are
    /// interchangeable).
    pub fn by_name(name: &str) -> Option<TargetDesc> {
        let canon = name.replace('_', "-");
        TargetDesc::builtins().into_iter().find(|t| t.name == canon)
    }

    /// Whether this target implements `op` (feature gate).
    pub fn supports_op(&self, op: Op) -> bool {
        self.features.supports_op(op)
    }

    /// Effective warp-builtin lowering for this target: hardware
    /// shfl/vote when both extensions exist, software emulation
    /// otherwise.
    pub fn default_warp_hw(&self) -> bool {
        self.features.shfl && self.features.vote
    }

    /// Stable byte serialization of every field that affects generated
    /// code, for cache fingerprints. Two targets that differ anywhere
    /// observable produce different streams.
    pub fn fingerprint_bytes(&self) -> Vec<u8> {
        let mut v = Vec::with_capacity(self.name.len() + 40);
        v.extend_from_slice(self.name.as_bytes());
        v.push(0);
        v.push(self.features.bits());
        for x in [
            self.caps.max_threads_per_warp,
            self.caps.max_warps_per_core,
            self.caps.max_cores,
            self.addr_map.data_base,
            self.addr_map.local_base,
            self.addr_map.stack_base,
            self.addr_map.stack_size,
            self.addr_map.heap_base,
        ] {
            v.extend_from_slice(&x.to_le_bytes());
        }
        for r in [
            self.regfile.num_int,
            self.regfile.num_float,
            self.regfile.float_base,
            self.regfile.int_alloc.0,
            self.regfile.int_alloc.1,
            self.regfile.float_alloc.0,
            self.regfile.float_alloc.1,
            self.regfile.arg_base,
            self.regfile.arg_count,
        ] {
            v.push(r);
        }
        v
    }
}

impl Default for TargetDesc {
    fn default() -> TargetDesc {
        TargetDesc::vortex()
    }
}

/// A target owns its divergence seeds (paper §4.3.1). Both built-in
/// profiles are Vortex-family machines — lane-indexed private stacks,
/// per-lane atomics, warp-uniform machine CSRs — so the Vortex tracker
/// rules apply; a non-Vortex target would implement this differently.
impl TargetDivergenceInfo for TargetDesc {
    fn is_source_of_divergence(
        &self,
        f: &Function,
        inst: &InstData,
        opts: &UniformityOptions,
    ) -> bool {
        VortexTti.is_source_of_divergence(f, inst, opts)
    }

    fn is_always_uniform(&self, f: &Function, inst: &InstData, opts: &UniformityOptions) -> bool {
        VortexTti.is_always_uniform(f, inst, opts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_lookup_and_names() {
        for name in TargetDesc::BUILTIN_NAMES {
            let t = TargetDesc::by_name(name).unwrap();
            assert_eq!(t.name, name);
        }
        // BUILTIN_NAMES is exactly the names of builtins(), in order —
        // builtins() is the single registration point.
        let names: Vec<&str> = TargetDesc::builtins().iter().map(|t| t.name).collect();
        assert_eq!(names, TargetDesc::BUILTIN_NAMES.to_vec());
        assert!(TargetDesc::by_name("nope").is_none());
        assert_eq!(TargetDesc::by_name("vortex_min").unwrap().name, "vortex-min");
        assert_eq!(TargetDesc::default().name, "vortex");
    }

    #[test]
    fn feature_gates() {
        let full = Features::vortex();
        let min = Features::minimal();
        assert!(full.supports_op(Op::CMOV) && full.supports_op(Op::SHFL));
        assert!(!min.supports_op(Op::CMOV));
        assert!(!min.supports_op(Op::SHFL));
        assert!(!min.supports_op(Op::BALLOT));
        assert!(min.supports_op(Op::FADD), "vortex-min keeps the FPU");
        assert!(min.supports_op(Op::SPLIT), "core divergence ops are base ISA");
        assert!(min.supports_op(Op::ADD) && min.supports_op(Op::BAR));
        let nofp = Features { fp: false, ..Features::minimal() };
        assert!(!nofp.supports_op(Op::FADD));
        assert!(!nofp.supports_op(Op::FSQRT));
        assert!(nofp.supports_op(Op::FMVXW), "bit moves are ALU-class");
        assert_ne!(full.bits(), min.bits());
        assert_eq!(Features::gate_name(Op::CMOV), Some("zicond"));
        assert_eq!(Features::gate_name(Op::ADD), None);
    }

    #[test]
    fn profiles_differ_where_they_should() {
        let v = TargetDesc::vortex();
        let m = TargetDesc::vortex_min();
        assert!(v.default_warp_hw());
        assert!(!m.default_warp_hw());
        assert_eq!(m.default_threads_per_warp, 32, "warp width pinned by VCL contract");
        assert!(m.caps.max_warps_per_core < v.caps.max_warps_per_core);
        assert!(m.caps.max_cores < v.caps.max_cores);
        assert_eq!(v.addr_map, m.addr_map, "both profiles share the Vortex memory map");
        assert_ne!(v.fingerprint_bytes(), m.fingerprint_bytes());
    }

    #[test]
    fn regfile_windows_must_avoid_reserved_registers() {
        assert!(RegFile::vortex().validate().is_ok());
        // Window reaching into the spill scratch (x29-x31): rejected.
        let bad = RegFile {
            int_alloc: (5, 31),
            ..RegFile::vortex()
        };
        assert!(bad.validate().unwrap_err().contains("spill scratch"));
        // Window covering x0/ra/sp: rejected.
        let bad = RegFile {
            int_alloc: (0, 28),
            ..RegFile::vortex()
        };
        assert!(bad.validate().is_err());
        // Float window into f61-f63: rejected.
        let bad = RegFile {
            float_alloc: (32, 63),
            ..RegFile::vortex()
        };
        assert!(bad.validate().is_err());
        // Unsupported bank shape: rejected.
        let bad = RegFile {
            num_int: 16,
            ..RegFile::vortex()
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn owned_tti_matches_vortex_tracker() {
        use crate::ir::{Builder, Csr, Function, Intr, Type, Val};
        let mut f = Function::new("t", vec![], Type::Void);
        let lane;
        {
            let mut b = Builder::new(&mut f);
            lane = b.intr(Intr::Csr(Csr::LaneId), vec![]);
            b.ret(None);
        }
        let Val::Inst(li) = lane else { panic!() };
        let opts = UniformityOptions::default();
        for t in TargetDesc::builtins() {
            assert!(t.is_source_of_divergence(&f, f.inst(li), &opts));
            assert!(!t.is_always_uniform(&f, f.inst(li), &opts));
        }
    }
}
