//! Function-argument uniformity analysis — paper Algorithm 1.
//!
//! Walks the call graph in reverse post-order, determining for every
//! internal-linkage function whether each argument is uniform at *all* call
//! sites (then the parameter is marked `uniform`) and whether the return
//! value is uniform. Iterates to convergence because argument refinement
//! can make more call-site actuals uniform, and return refinement can make
//! caller values uniform.
//!
//! This is the "Uni-Func" ladder step of the evaluation (Fig. 7/8).

use super::callgraph::CallGraph;
use super::tti::TargetDivergenceInfo;
use super::{uniformity, UniformityOptions};
use crate::ir::{FuncId, InstKind, Linkage, Module, Val};

/// Result: which (function, param) pairs were newly proven uniform.
#[derive(Debug, Default)]
pub struct FuncArgReport {
    pub params_marked: Vec<(String, usize)>,
    pub rets_marked: Vec<String>,
    pub iterations: u32,
}

pub fn run(m: &mut Module, opts: &UniformityOptions, tti: &dyn TargetDivergenceInfo) -> FuncArgReport {
    let mut report = FuncArgReport::default();
    if !opts.uni_func {
        return report;
    }
    let roots: Vec<FuncId> = (0..m.funcs.len() as u32)
        .map(FuncId)
        .filter(|f| m.funcs[f.idx()].is_kernel || m.funcs[f.idx()].linkage == Linkage::External)
        .collect();
    let cg = CallGraph::build(m);
    let order = cg.rpo_from(&roots);
    // Fixpoint over the whole SCC-free ordering (recursion falls out
    // conservatively: a cycle just never refines).
    for iter in 0..8 {
        report.iterations = iter + 1;
        let mut changed = false;
        for &fid in &order {
            // (1) Argument refinement: internal functions whose every call
            // site passes a uniform actual.
            if m.func(fid).linkage == Linkage::Internal && !m.func(fid).params.is_empty() {
                let sites = CallGraph::call_sites(m, fid);
                if !sites.is_empty() {
                    let nparams = m.func(fid).params.len();
                    let mut all_uniform = vec![true; nparams];
                    for (caller, inst) in &sites {
                        let u = uniformity::analyze(m, *caller, opts, tti);
                        let cf = m.func(*caller);
                        if let InstKind::Call { args, .. } = &cf.inst(*inst).kind {
                            for (pi, a) in args.iter().enumerate() {
                                if u.val_div(*a) {
                                    all_uniform[pi] = false;
                                }
                            }
                        }
                    }
                    for (pi, ok) in all_uniform.iter().enumerate() {
                        let p = &mut m.func_mut(fid).params[pi];
                        if *ok && !p.uniform {
                            p.uniform = true;
                            changed = true;
                            report
                                .params_marked
                                .push((m.func(fid).name.clone(), pi));
                        }
                    }
                }
            }
            // (2) Return refinement: all returned values uniform under the
            // current assumptions.
            if m.func(fid).ret != crate::ir::Type::Void && !m.func(fid).ret_uniform {
                let u = uniformity::analyze(m, fid, opts, tti);
                let f = m.func(fid);
                let all_rets_uniform = f
                    .insts
                    .iter()
                    .filter(|i| !i.dead)
                    .filter_map(|i| match &i.kind {
                        InstKind::Ret { val: Some(v) } => Some(*v),
                        _ => None,
                    })
                    .all(|v| !u.val_div(v));
                let any_ret = f
                    .insts
                    .iter()
                    .filter(|i| !i.dead)
                    .any(|i| matches!(i.kind, InstKind::Ret { val: Some(_) }));
                if any_ret && all_rets_uniform {
                    m.func_mut(fid).ret_uniform = true;
                    changed = true;
                    report.rets_marked.push(m.func(fid).name.clone());
                }
            }
        }
        if !changed {
            break;
        }
    }
    report
}

/// Convenience for tests: the set of values a caller passes at a call.
pub fn call_actuals(m: &Module, caller: FuncId, callee: FuncId) -> Vec<Vec<Val>> {
    let mut out = vec![];
    for inst in m.func(caller).insts.iter().filter(|i| !i.dead) {
        if let InstKind::Call { callee: c, args } = &inst.kind {
            if *c == callee {
                out.push(args.clone());
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::tti::VortexTti;
    use crate::ir::*;

    /// helper(n) loops to n; kernel calls helper(len) where len is a
    /// uniform kernel param. Algorithm 1 must mark helper's param uniform
    /// and its return uniform.
    fn build() -> Module {
        let mut m = Module::new("t");
        let mut h = Function::new(
            "helper",
            vec![Param {
                name: "n".into(),
                ty: Type::I32,
                uniform: false,
            }],
            Type::I32,
        );
        h.linkage = Linkage::Internal;
        let entry = h.entry;
        let hh = h.add_block("h");
        let body = h.add_block("body");
        let exit = h.add_block("exit");
        {
            let mut b = Builder::at(&mut h, entry);
            b.br(hh);
            b.set_block(hh);
            let i = b.phi(Type::I32, vec![(entry, Val::ci(0))]);
            let c = b.icmp(ICmp::Slt, i, Val::Arg(0));
            b.cond_br(c, body, exit);
            b.set_block(body);
            let i2 = b.add(i, Val::ci(1));
            b.br(hh);
            b.set_block(exit);
            b.ret(Some(i));
            if let Val::Inst(ip) = i {
                if let InstKind::Phi { incs } = &mut b.f.inst_mut(ip).kind {
                    incs.push((body, i2));
                }
            }
        }
        let h_id = m.add_func(h);
        let mut k = Function::new(
            "k",
            vec![Param {
                name: "len".into(),
                ty: Type::I32,
                uniform: true,
            }],
            Type::Void,
        );
        k.is_kernel = true;
        k.linkage = Linkage::External;
        {
            let mut b = Builder::new(&mut k);
            let _ = b.call(h_id, vec![Val::Arg(0)], Type::I32);
            b.ret(None);
        }
        m.add_func(k);
        m
    }

    #[test]
    fn marks_uniform_args_and_ret() {
        let mut m = build();
        let opts = UniformityOptions::all();
        let report = run(&mut m, &opts, &VortexTti);
        let h = m.find_func("helper").unwrap();
        assert!(m.func(h).params[0].uniform, "param should be inferred uniform");
        assert!(m.func(h).ret_uniform, "ret should be inferred uniform");
        assert!(!report.params_marked.is_empty());
        assert!(!report.rets_marked.is_empty());
    }

    #[test]
    fn divergent_site_blocks_refinement() {
        let mut m = build();
        // Add a second caller passing a divergent value.
        let h = m.find_func("helper").unwrap();
        let mut k2 = Function::new("k2", vec![], Type::Void);
        k2.is_kernel = true;
        k2.linkage = Linkage::External;
        {
            let mut b = Builder::new(&mut k2);
            let lane = b.intr(Intr::Csr(Csr::LaneId), vec![]);
            let _ = b.call(h, vec![lane], Type::I32);
            b.ret(None);
        }
        m.add_func(k2);
        let opts = UniformityOptions::all();
        run(&mut m, &opts, &VortexTti);
        assert!(!m.func(h).params[0].uniform);
        assert!(!m.func(h).ret_uniform);
    }

    #[test]
    fn disabled_without_uni_func() {
        let mut m = build();
        let opts = UniformityOptions {
            uni_hw: true,
            uni_ann: true,
            uni_func: false,
        };
        let report = run(&mut m, &opts, &VortexTti);
        assert_eq!(report.iterations, 0);
        let h = m.find_func("helper").unwrap();
        assert!(!m.func(h).params[0].uniform);
    }
}
