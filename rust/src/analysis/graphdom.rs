//! Graph-generic iterative dominators (Cooper–Harvey–Kennedy).
//!
//! The IR has its own [`crate::ir::DomTree`] keyed on [`crate::ir::BlockId`];
//! this module is the *shared* computation for every other block graph in
//! the stack — MIR in [`crate::backend::combine`], and anything else shaped
//! as `usize` nodes with a successor closure. One implementation, one set
//! of edge-case fixes (unreachable blocks, self-loop entries).

/// Immediate dominators plus dominator-tree depth for a graph of `n`
/// nodes given by a successor closure. `idom[entry]` is `None` (the
/// entry has no strict dominator) and unreachable nodes get `None` with
/// depth 0. Successors `>= n` are ignored (MIR terminators may carry
/// out-of-range sentinel targets).
pub fn dominators(
    n: usize,
    entry: usize,
    mut succs_of: impl FnMut(usize) -> Vec<usize>,
) -> (Vec<Option<usize>>, Vec<u32>) {
    if n == 0 {
        return (vec![], vec![]);
    }
    let succs: Vec<Vec<usize>> = (0..n).map(&mut succs_of).collect();
    let mut preds: Vec<Vec<usize>> = vec![vec![]; n];
    for (b, ss) in succs.iter().enumerate() {
        for &s in ss {
            if s < n {
                preds[s].push(b);
            }
        }
    }
    // Reverse post-order over reachable nodes (iterative DFS).
    let mut order: Vec<usize> = vec![];
    let mut seen = vec![false; n];
    let mut stack: Vec<(usize, usize)> = vec![(entry, 0)];
    seen[entry] = true;
    while let Some(frame) = stack.last_mut() {
        let (b, k) = *frame;
        if k < succs[b].len() {
            frame.1 += 1;
            let s = succs[b][k];
            if s < n && !seen[s] {
                seen[s] = true;
                stack.push((s, 0));
            }
        } else {
            order.push(b);
            stack.pop();
        }
    }
    order.reverse();
    let mut rpo_num = vec![usize::MAX; n];
    for (k, &b) in order.iter().enumerate() {
        rpo_num[b] = k;
    }
    let mut idom: Vec<Option<usize>> = vec![None; n];
    idom[entry] = Some(entry);
    fn intersect(idom: &[Option<usize>], rpo_num: &[usize], mut a: usize, mut b: usize) -> usize {
        while a != b {
            while rpo_num[a] > rpo_num[b] {
                a = idom[a].unwrap();
            }
            while rpo_num[b] > rpo_num[a] {
                b = idom[b].unwrap();
            }
        }
        a
    }
    let mut changed = true;
    while changed {
        changed = false;
        for &b in order.iter().skip(1) {
            let mut new: Option<usize> = None;
            for &p in &preds[b] {
                if idom[p].is_none() {
                    continue;
                }
                new = Some(match new {
                    None => p,
                    Some(x) => intersect(&idom, &rpo_num, x, p),
                });
            }
            if new.is_some() && new != idom[b] {
                idom[b] = new;
                changed = true;
            }
        }
    }
    idom[entry] = None; // entry has no strict dominator
    let mut depth = vec![0u32; n];
    for &b in &order {
        if let Some(p) = idom[b] {
            depth[b] = depth[p] + 1;
        }
    }
    (idom, depth)
}

/// Strict dominance via the idom chain (convenience over the
/// [`dominators`] result; O(tree height) per query).
pub fn strictly_dominates(idom: &[Option<usize>], a: usize, b: usize) -> bool {
    let mut x = b;
    while let Some(p) = idom[x] {
        if p == a {
            return true;
        }
        x = p;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diamond() {
        // 0 -> {1, 2} -> 3
        let succs = [vec![1, 2], vec![3], vec![3], vec![]];
        let (idom, depth) = dominators(4, 0, |b| succs[b].clone());
        assert_eq!(idom, vec![None, Some(0), Some(0), Some(0)]);
        assert_eq!(depth, vec![0, 1, 1, 1]);
        assert!(strictly_dominates(&idom, 0, 3));
        assert!(!strictly_dominates(&idom, 1, 3));
        assert!(!strictly_dominates(&idom, 3, 3));
    }

    #[test]
    fn loop_with_unreachable_and_bogus_edge() {
        // 0 -> 1 -> 2 -> 1 (backedge), node 3 unreachable, and node 2
        // also lists an out-of-range successor (ignored).
        let succs = [vec![1], vec![2], vec![1, 9], vec![0]];
        let (idom, depth) = dominators(4, 0, |b| succs[b].clone());
        assert_eq!(idom, vec![None, Some(0), Some(1), None]);
        assert_eq!(depth, vec![0, 1, 2, 0]);
        assert!(strictly_dominates(&idom, 1, 2));
    }

    #[test]
    fn empty_graph() {
        let (idom, depth) = dominators(0, 0, |_| vec![]);
        assert!(idom.is_empty() && depth.is_empty());
    }
}
