//! Uniformity (divergence) analysis — paper §4.3.1.
//!
//! Mirrors LLVM's UniformityAnalysis structure: seed values from the
//! target's divergence tracker (TTI), then propagate along def-use chains
//! and control-dependence (sync dependence) until fixpoint. Two
//! SIMT-specific effects are modeled:
//!
//! * **join-point divergence** — phis reachable between a divergent branch
//!   and its IPDOM merge lane-varying control decisions;
//! * **temporal divergence** — values defined inside a loop with a
//!   divergent exiting branch are divergent at any use outside the loop
//!   (lanes leave at different iterations).
//!
//! The annotation analysis (paper: metadata `vortex.uniform`, `uniform`
//! qualifiers, stack-slot reasoning) is folded in via `uniform_ann` flags,
//! `Param::uniform`, and the alloca store tracking below.

use super::tti::TargetDivergenceInfo;
use super::UniformityOptions;
use crate::ir::cfg::reachable_until;
use crate::ir::dom::{DomTree, PostDomTree};
use crate::ir::loops::LoopInfo;
use crate::ir::*;
use std::collections::HashSet;

#[derive(Debug)]
pub struct Uniformity {
    /// Per-instruction divergence (indexed by InstId).
    pub inst_div: Vec<bool>,
    /// Per-argument divergence.
    pub arg_div: Vec<bool>,
    /// Blocks whose conditional terminator has a divergent condition.
    pub div_branch_blocks: HashSet<BlockId>,
}

impl Uniformity {
    pub fn val_div(&self, v: Val) -> bool {
        match v {
            Val::Inst(i) => self.inst_div[i.idx()],
            Val::Arg(i) => self.arg_div[i as usize],
            Val::I(..) | Val::F(..) | Val::G(..) => false,
        }
    }

    /// Is the terminator of block `b` a uniform branch? (Algorithm 2,
    /// IS_UNIFORM)
    pub fn branch_uniform(&self, b: BlockId) -> bool {
        !self.div_branch_blocks.contains(&b)
    }

    pub fn num_divergent(&self) -> usize {
        self.inst_div.iter().filter(|&&d| d).count()
    }

    /// The SIMT-safety walk shared by the O3 redundancy passes: walk the
    /// dominator chain from `from` (exclusive) up to `to`; return true if
    /// a block whose terminator is a divergent branch — and that `exempt`
    /// does not excuse — lies on the path. `to` itself is checked only
    /// when `check_to` (GVN checks the defining block's split; LICM stops
    /// short of the loop header, whose branch is the loop test). A chain
    /// that never reaches `to` counts as crossing (conservative).
    ///
    /// Scope of the guarantee: this detects *dominating* divergent splits
    /// — every split whose region the whole `from` block sits inside. A
    /// divergent branch that does not dominate `from` (e.g. `from` is a
    /// merge block also reachable around the split) is not on the chain
    /// and is deliberately not a barrier: SSA dominance ensures every
    /// lane active at `from` executed the definition, and the per-lane
    /// register file preserves inactive lanes' values across mask
    /// changes, so reusing a value across a reconvergence point is
    /// mask-safe. The barrier exists to keep divergent live ranges out of
    /// the split regions they would otherwise span end-to-end.
    pub fn crosses_divergent_branch(
        &self,
        dom: &DomTree,
        from: BlockId,
        to: BlockId,
        check_to: bool,
        exempt: &dyn Fn(BlockId) -> bool,
    ) -> bool {
        let mut cur = from;
        while cur != to {
            match dom.idom[cur.idx()] {
                Some(d) => cur = d,
                None => return true,
            }
            if cur == to && !check_to {
                break;
            }
            if self.div_branch_blocks.contains(&cur) && !exempt(cur) {
                return true;
            }
        }
        false
    }
}

/// Trace a pointer value to its root: an alloca, a global, or unknown.
fn ptr_root(f: &Function, mut v: Val) -> PtrRoot {
    loop {
        match v {
            Val::Inst(i) => match &f.inst(i).kind {
                InstKind::Gep { base, .. } => v = *base,
                InstKind::Alloca { .. } => return PtrRoot::Alloca(i),
                _ => return PtrRoot::Unknown,
            },
            Val::G(g) => return PtrRoot::Global(g),
            Val::Arg(a) => return PtrRoot::Arg(a),
            _ => return PtrRoot::Unknown,
        }
    }
}

#[derive(PartialEq, Eq, Clone, Copy, Debug)]
enum PtrRoot {
    Alloca(InstId),
    Global(GlobalId),
    Arg(u32),
    Unknown,
}

pub fn analyze(
    m: &Module,
    fid: FuncId,
    opts: &UniformityOptions,
    tti: &dyn TargetDivergenceInfo,
) -> Uniformity {
    let f = m.func(fid);
    let pdom = PostDomTree::build(f);
    let li = LoopInfo::build(f);
    analyze_with(m, fid, opts, tti, &pdom, &li)
}

/// [`analyze`] with the function's cached dominator trees (callers holding
/// `&mut Module` get the CFG-version-checked cache for free; the loop info
/// is derived from the cached forward tree instead of a fresh build).
pub fn analyze_cached(
    m: &mut Module,
    fid: FuncId,
    opts: &UniformityOptions,
    tti: &dyn TargetDivergenceInfo,
) -> Uniformity {
    let (dom, pdom) = {
        let f = m.func_mut(fid);
        (f.dom_tree(), f.pdom_tree())
    };
    let li = LoopInfo::build_with(m.func(fid), &dom);
    analyze_with(m, fid, opts, tti, &pdom, &li)
}

/// The fixpoint core, parameterized over caller-supplied analyses.
pub fn analyze_with(
    m: &Module,
    fid: FuncId,
    opts: &UniformityOptions,
    tti: &dyn TargetDivergenceInfo,
    pdom: &PostDomTree,
    li: &LoopInfo,
) -> Uniformity {
    let f = m.func(fid);
    let n = f.insts.len();
    let mut div = vec![false; n];
    // `uniform` parameter markings come from user annotations or the
    // Algorithm-1 refinement — both are honoured only from the Uni-Ann
    // ladder step up (paper §5.2).
    let arg_div: Vec<bool> = f
        .params
        .iter()
        .map(|p| !(opts.uni_ann && p.uniform))
        .collect();
    // Values forced divergent by control dependence (phis at joins,
    // loop-escaping values).
    let mut forced: HashSet<InstId> = HashSet::new();
    let mut processed_branches: HashSet<BlockId> = HashSet::new();

    // Alloca uniformity: an alloca slot is "uniform storage" if every store
    // through it stores a uniform value at a uniform index and its address
    // never escapes. Iterated with the main fixpoint. (paper: annotation
    // analysis, stack-variable reasoning — gated on Uni-Ann.)
    let allocas: Vec<InstId> = (0..n as u32)
        .map(InstId)
        .filter(|&i| !f.insts[i.idx()].dead && matches!(f.inst(i).kind, InstKind::Alloca { .. }))
        .collect();
    let mut alloca_uniform: std::collections::HashMap<InstId, bool> = allocas
        .iter()
        .map(|&a| (a, opts.uni_ann && !alloca_escapes(f, a)))
        .collect();

    let rpo = f.rpo();
    let val_div = |div: &Vec<bool>, v: Val| -> bool {
        match v {
            Val::Inst(i) => div[i.idx()],
            Val::Arg(i) => arg_div[i as usize],
            _ => false,
        }
    };

    loop {
        let mut changed = false;
        for &b in &rpo {
            for &id in &f.blocks[b.idx()].insts {
                if div[id.idx()] {
                    continue;
                }
                let inst = f.inst(id);
                // Annotation override (Uni-Ann): a user-asserted uniform
                // value stops propagation here.
                if opts.uni_ann && inst.uniform_ann {
                    continue;
                }
                if tti.is_always_uniform(f, inst, opts) {
                    continue;
                }
                let mut d = tti.is_source_of_divergence(f, inst, opts) || forced.contains(&id);
                if !d {
                    d = match &inst.kind {
                        InstKind::Load { ptr } => {
                            // Private (stack) slots: the per-lane base
                            // address is always divergent, but the *slot
                            // contents* are uniform when every store is a
                            // uniform value at a uniform index under
                            // uniform control (annotation analysis).
                            if let PtrRoot::Alloca(a) = ptr_root(f, *ptr) {
                                !(*alloca_uniform.get(&a).unwrap_or(&false)
                                    && gep_indices_uniform(f, *ptr, &|v| val_div(&div, v)))
                            } else if val_div(&div, *ptr) {
                                true
                            } else {
                                !load_is_uniform(m, f, *ptr, opts)
                            }
                        }
                        InstKind::Call { callee, args } => {
                            let cf = m.func(*callee);
                            // Return uniform only if inferred/marked AND the
                            // per-site uniform params actually receive
                            // uniform values here.
                            if !cf.ret_uniform {
                                true
                            } else {
                                cf.params
                                    .iter()
                                    .zip(args.iter())
                                    .any(|(p, a)| p.uniform && val_div(&div, *a))
                            }
                        }
                        InstKind::SplitBr { .. } => false, // token is warp-level
                        k => k.operands().iter().any(|&v| val_div(&div, v)),
                    };
                }
                if d {
                    div[id.idx()] = true;
                    changed = true;
                }
            }
        }
        // Re-evaluate alloca uniform storage: every store must write a
        // uniform value at a uniform index, from a block whose control
        // dependences are all uniform (otherwise some lanes skip the
        // store and slot contents diverge).
        let cdg_deps = crate::ir::cdg::Cdg::build_with(f, pdom);
        for &a in &allocas {
            if !alloca_uniform[&a] {
                continue;
            }
            let mut ok = true;
            for inst in f.insts.iter() {
                if inst.dead {
                    continue;
                }
                if let InstKind::Store { ptr, val } = &inst.kind {
                    if ptr_root(f, *ptr) == PtrRoot::Alloca(a) {
                        let store_ctl_div = cdg_deps.deps[inst.block.idx()].iter().any(|dep| {
                            let t = f.term(*dep);
                            match &f.inst(t).kind {
                                InstKind::CondBr { cond, .. }
                                | InstKind::SplitBr { cond, .. }
                                | InstKind::PredBr { cond, .. } => val_div(&div, *cond),
                                _ => false,
                            }
                        });
                        if val_div(&div, *val)
                            || !gep_indices_uniform(f, *ptr, &|v| val_div(&div, v))
                            || store_ctl_div
                        {
                            ok = false;
                            break;
                        }
                    }
                }
            }
            if !ok {
                alloca_uniform.insert(a, false);
                changed = true;
            }
        }
        // Control-dependence (sync dependence) effects of newly divergent
        // branches.
        for &b in &rpo {
            if processed_branches.contains(&b) {
                continue;
            }
            let term = f.term(b);
            let cond = match &f.inst(term).kind {
                InstKind::CondBr { cond, .. }
                | InstKind::SplitBr { cond, .. }
                | InstKind::PredBr { cond, .. } => Some(*cond),
                _ => None,
            };
            let Some(cond) = cond else { continue };
            if !val_div(&div, cond) && !div[term.idx()] {
                continue;
            }
            processed_branches.insert(b);
            changed = true;
            let succs = f.succs(b);
            let ip = pdom.ipdom_of(b);
            // Sync dependence: lanes that took different arms merge at the
            // branch's IPDOM and at any block both arms reach — phis there
            // observe lane-dependent control decisions. Phis strictly
            // inside a single arm (e.g. a loop header within the arm) stay
            // uniform: every active lane reached them the same way.
            let stop = ip.unwrap_or(BlockId(u32::MAX));
            let r1 = reachable_until(f, &succs[..1.min(succs.len())], stop);
            let r2 = if succs.len() > 1 {
                reachable_until(f, &succs[1..], stop)
            } else {
                Default::default()
            };
            let mut mark_blocks: Vec<BlockId> =
                r1.intersection(&r2).copied().collect();
            if let Some(ip) = ip {
                mark_blocks.push(ip);
            }
            for x in mark_blocks {
                for &id in &f.blocks[x.idx()].insts {
                    if matches!(f.inst(id).kind, InstKind::Phi { .. }) {
                        forced.insert(id);
                    } else {
                        break;
                    }
                }
            }
            // Temporal divergence: divergent branch that can leave its
            // loop makes loop-defined values divergent outside the loop.
            if let Some(l) = li.innermost(b) {
                let leaves_loop = succs.iter().any(|s| !l.blocks.contains(s))
                    || ip.map(|ip| !l.blocks.contains(&ip)).unwrap_or(true);
                if leaves_loop {
                    for (idx, inst) in f.insts.iter().enumerate() {
                        if inst.dead || inst.ty == Type::Void {
                            continue;
                        }
                        if !l.blocks.contains(&inst.block) {
                            continue;
                        }
                        let id = InstId(idx as u32);
                        // any use outside the loop?
                        let escapes = f.insts.iter().enumerate().any(|(uidx, u)| {
                            !u.dead
                                && !l.blocks.contains(&u.block)
                                && u.kind.operands().contains(&Val::Inst(id))
                                && uidx != idx
                        });
                        if escapes {
                            forced.insert(id);
                        }
                    }
                }
            }
        }
        if !changed {
            break;
        }
    }

    // Divergent-branch block set.
    let mut div_branch_blocks = HashSet::new();
    for &b in &rpo {
        let term = f.term(b);
        let divb = match &f.inst(term).kind {
            InstKind::CondBr { cond, .. }
            | InstKind::SplitBr { cond, .. }
            | InstKind::PredBr { cond, .. } => val_div(&div, *cond),
            _ => false,
        };
        if divb {
            div_branch_blocks.insert(b);
        }
    }
    Uniformity {
        inst_div: div,
        arg_div,
        div_branch_blocks,
    }
}

/// Does the alloca's address escape (passed to a call / stored / returned)?
fn alloca_escapes(f: &Function, a: InstId) -> bool {
    for inst in f.insts.iter().filter(|i| !i.dead) {
        match &inst.kind {
            InstKind::Load { .. } => {}
            InstKind::Store { ptr, val } => {
                // storing the pointer itself somewhere = escape
                if ptr_root_is(f, *val, a) && !ptr_root_is(f, *ptr, a) {
                    return true;
                }
            }
            InstKind::Gep { .. } => {}
            k => {
                if k.operands().iter().any(|&v| ptr_root_is(f, v, a)) {
                    return true;
                }
            }
        }
    }
    false
}

fn ptr_root_is(f: &Function, v: Val, a: InstId) -> bool {
    ptr_root(f, v) == PtrRoot::Alloca(a)
}

/// Are the GEP indices along the pointer chain uniform?
fn gep_indices_uniform(f: &Function, mut v: Val, val_div: &dyn Fn(Val) -> bool) -> bool {
    loop {
        match v {
            Val::Inst(i) => match &f.inst(i).kind {
                InstKind::Gep { base, index, .. } => {
                    if val_div(*index) {
                        return false;
                    }
                    v = *base;
                }
                _ => return true,
            },
            _ => return true,
        }
    }
}

/// Is a load through `ptr` (already known to have a uniform address)
/// guaranteed to produce a uniform value?
fn load_is_uniform(m: &Module, f: &Function, ptr: Val, opts: &UniformityOptions) -> bool {
    match ptr_root(f, ptr) {
        PtrRoot::Alloca(_) => unreachable!("handled by caller"),
        PtrRoot::Global(g) => {
            let gl = &m.globals[g.idx()];
            if gl.space == AddrSpace::Const {
                // The kernel argument block is uniform by hardware
                // construction (Uni-HW); other constant buffers are covered
                // by the annotation analysis (Uni-Ann).
                if gl.name == "__args" {
                    opts.uni_hw
                } else {
                    opts.uni_ann
                }
            } else {
                false
            }
        }
        // Loads through pointer arguments / unknown roots may race with
        // other lanes' stores: conservatively divergent.
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::tti::VortexTti;
    use crate::ir::{Builder, Param};

    fn opts_all() -> UniformityOptions {
        UniformityOptions::all()
    }

    /// gid-dependent branch is divergent; uniform-arg loop is uniform.
    #[test]
    fn divergent_gid_branch() {
        let mut m = Module::new("t");
        let mut f = Function::new(
            "k",
            vec![Param {
                name: "n".into(),
                ty: Type::I32,
                uniform: true,
            }],
            Type::Void,
        );
        let t = f.add_block("t");
        let e = f.add_block("e");
        let entry = f.entry;
        let mut b = Builder::new(&mut f);
        let gid = b.intr(Intr::WorkItem(WorkItem::GlobalId), vec![Val::ci(0)]);
        let c = b.icmp(ICmp::Slt, gid, Val::Arg(0));
        b.cond_br(c, t, e);
        b.set_block(t);
        b.br(e);
        b.set_block(e);
        b.ret(None);
        let fid = m.add_func(f);
        let u = analyze(&m, fid, &opts_all(), &VortexTti);
        assert!(u.val_div(gid));
        assert!(u.val_div(c));
        assert!(u.div_branch_blocks.contains(&entry));
    }

    /// Loop on a uniform bound: branch uniform, induction phi uniform.
    #[test]
    fn uniform_loop() {
        let mut m = Module::new("t");
        let mut f = Function::new(
            "k",
            vec![Param {
                name: "n".into(),
                ty: Type::I32,
                uniform: true,
            }],
            Type::Void,
        );
        let entry = f.entry;
        let h = f.add_block("h");
        let body = f.add_block("body");
        let exit = f.add_block("exit");
        let mut b = Builder::at(&mut f, entry);
        b.br(h);
        b.set_block(h);
        let i = b.phi(Type::I32, vec![(entry, Val::ci(0))]);
        let c = b.icmp(ICmp::Slt, i, Val::Arg(0));
        b.cond_br(c, body, exit);
        b.set_block(body);
        let i2 = b.add(i, Val::ci(1));
        b.br(h);
        b.set_block(exit);
        b.ret(None);
        if let Val::Inst(ip) = i {
            if let InstKind::Phi { incs } = &mut f.inst_mut(ip).kind {
                incs.push((body, i2));
            }
        }
        let fid = m.add_func(f);
        let u = analyze(&m, fid, &opts_all(), &VortexTti);
        assert!(!u.val_div(i));
        assert!(!u.val_div(c));
        assert!(u.branch_uniform(h));
        // Same loop with a non-uniform bound is divergent.
        let mut m2 = m.clone();
        m2.funcs[0].params[0].uniform = false;
        let u2 = analyze(&m2, FuncId(0), &opts_all(), &VortexTti);
        assert!(u2.val_div(c));
        assert!(!u2.branch_uniform(h));
    }

    /// Phi at the join of a divergent branch is divergent even with
    /// uniform incomings (sync dependence).
    #[test]
    fn join_phi_divergent() {
        let mut m = Module::new("t");
        let mut f = Function::new("k", vec![], Type::Void);
        let t = f.add_block("t");
        let e = f.add_block("e");
        let j = f.add_block("j");
        let entry = f.entry;
        let mut b = Builder::at(&mut f, entry);
        let lane = b.intr(Intr::Csr(Csr::LaneId), vec![]);
        let c = b.icmp(ICmp::Eq, lane, Val::ci(0));
        b.cond_br(c, t, e);
        b.set_block(t);
        b.br(j);
        b.set_block(e);
        b.br(j);
        b.set_block(j);
        let p = b.phi(Type::I32, vec![(t, Val::ci(1)), (e, Val::ci(2))]);
        b.ret(None);
        let fid = m.add_func(f);
        let u = analyze(&m, fid, &opts_all(), &VortexTti);
        assert!(u.val_div(p));
    }

    /// Temporal divergence: value from a loop with divergent exit is
    /// divergent outside the loop.
    #[test]
    fn loop_escape_divergence() {
        let mut m = Module::new("t");
        let mut f = Function::new("k", vec![], Type::I32);
        let entry = f.entry;
        let h = f.add_block("h");
        let body = f.add_block("body");
        let exit = f.add_block("exit");
        let mut b = Builder::at(&mut f, entry);
        let lane = b.intr(Intr::Csr(Csr::LaneId), vec![]);
        b.br(h);
        b.set_block(h);
        let i = b.phi(Type::I32, vec![(entry, Val::ci(0))]);
        let c = b.icmp(ICmp::Slt, i, lane); // divergent bound
        b.cond_br(c, body, exit);
        b.set_block(body);
        let i2 = b.add(i, Val::ci(1));
        b.br(h);
        b.set_block(exit);
        b.ret(Some(i2)); // i2 escapes the loop
        if let Val::Inst(ip) = i {
            if let InstKind::Phi { incs } = &mut f.inst_mut(ip).kind {
                incs.push((body, i2));
            }
        }
        let fid = m.add_func(f);
        let u = analyze(&m, fid, &opts_all(), &VortexTti);
        assert!(u.val_div(i2));
    }

    /// Vote results are uniform; branch on a vote is uniform.
    #[test]
    fn vote_uniform_branch() {
        let mut m = Module::new("t");
        let mut f = Function::new("k", vec![], Type::Void);
        let entry = f.entry;
        let t = f.add_block("t");
        let e = f.add_block("e");
        let mut b = Builder::at(&mut f, entry);
        let lane = b.intr(Intr::Csr(Csr::LaneId), vec![]);
        let c = b.icmp(ICmp::Eq, lane, Val::ci(0));
        let v = b.intr(Intr::VoteAny, vec![c]);
        b.cond_br(v, t, e);
        b.set_block(t);
        b.br(e);
        b.set_block(e);
        b.ret(None);
        let fid = m.add_func(f);
        let u = analyze(&m, fid, &opts_all(), &VortexTti);
        assert!(!u.val_div(v));
        assert!(u.branch_uniform(entry));
    }

    /// Annotation override: a `vortex.uniform`-annotated load is uniform
    /// under Uni-Ann, divergent without it.
    #[test]
    fn annotation_override() {
        let mut m = Module::new("t");
        let mut f = Function::new(
            "k",
            vec![Param {
                name: "p".into(),
                ty: Type::Ptr(AddrSpace::Global),
                uniform: true,
            }],
            Type::Void,
        );
        let l;
        {
            let mut b = Builder::new(&mut f);
            l = b.load(Val::Arg(0), Type::I32);
            b.ret(None);
        }
        if let Val::Inst(li) = l {
            f.inst_mut(li).uniform_ann = true;
        }
        let fid = m.add_func(f);
        let with_ann = analyze(&m, fid, &opts_all(), &VortexTti);
        assert!(!with_ann.val_div(l));
        let no_ann = analyze(
            &m,
            fid,
            &UniformityOptions {
                uni_hw: true,
                uni_ann: false,
                uni_func: false,
            },
            &VortexTti,
        );
        assert!(no_ann.val_div(l));
    }

    /// A uniform branch guarding a divergent body stays uniform: only
    /// the condition decides branch divergence, and the merge phi turns
    /// divergent through plain data dependence, not sync dependence.
    #[test]
    fn uniform_branch_divergent_body() {
        let mut m = Module::new("t");
        let mut f = Function::new(
            "k",
            vec![Param {
                name: "n".into(),
                ty: Type::I32,
                uniform: true,
            }],
            Type::Void,
        );
        let entry = f.entry;
        let t = f.add_block("t");
        let j = f.add_block("j");
        let mut b = Builder::at(&mut f, entry);
        let c = b.icmp(ICmp::Slt, Val::Arg(0), Val::ci(10));
        b.cond_br(c, t, j);
        b.set_block(t);
        let lane = b.intr(Intr::Csr(Csr::LaneId), vec![]);
        let dv = b.add(lane, Val::ci(1));
        b.br(j);
        b.set_block(j);
        let p = b.phi(Type::I32, vec![(entry, Val::ci(0)), (t, dv)]);
        b.ret(None);
        let fid = m.add_func(f);
        let u = analyze(&m, fid, &opts_all(), &VortexTti);
        assert!(!u.val_div(c));
        assert!(u.branch_uniform(entry), "uniform cond keeps the branch uniform");
        assert!(!u.div_branch_blocks.contains(&entry));
        assert!(u.val_div(dv), "body value is still divergent");
        assert!(u.val_div(p), "divergent incoming flows through the merge phi");
    }

    /// A divergent branch fully contained in a loop body does not poison
    /// the loop: with a uniform exit condition the induction phi, its
    /// escaping value, and the header branch all stay uniform (the
    /// divergence reconverges at the latch, so there is no temporal
    /// divergence).
    #[test]
    fn divergent_body_uniform_exit_loop() {
        let mut m = Module::new("t");
        let mut f = Function::new(
            "k",
            vec![Param {
                name: "n".into(),
                ty: Type::I32,
                uniform: true,
            }],
            Type::I32,
        );
        let entry = f.entry;
        let h = f.add_block("h");
        let body = f.add_block("body");
        let odd = f.add_block("odd");
        let latch = f.add_block("latch");
        let exit = f.add_block("exit");
        let mut b = Builder::at(&mut f, entry);
        let lane = b.intr(Intr::Csr(Csr::LaneId), vec![]);
        b.br(h);
        b.set_block(h);
        let i = b.phi(Type::I32, vec![(entry, Val::ci(0))]);
        let c = b.icmp(ICmp::Slt, i, Val::Arg(0));
        b.cond_br(c, body, exit);
        b.set_block(body);
        let lc = b.icmp(ICmp::Eq, lane, Val::ci(0));
        b.cond_br(lc, odd, latch);
        b.set_block(odd);
        b.br(latch);
        b.set_block(latch);
        let i2 = b.add(i, Val::ci(1));
        b.br(h);
        b.set_block(exit);
        b.ret(Some(i2));
        if let Val::Inst(ip) = i {
            if let InstKind::Phi { incs } = &mut f.inst_mut(ip).kind {
                incs.push((latch, i2));
            }
        }
        let fid = m.add_func(f);
        let u = analyze(&m, fid, &opts_all(), &VortexTti);
        assert!(u.val_div(lc), "lane-dependent inner branch is divergent");
        assert!(!u.branch_uniform(body));
        assert!(!u.val_div(i), "induction phi stays uniform");
        assert!(!u.val_div(i2), "escaping value stays uniform");
        assert!(u.branch_uniform(h), "uniform exit keeps the loop uniform");
    }

    /// A select over a lane-dependent condition is divergent even with
    /// constant arms — exactly what the barrier checks must see when a
    /// select feeds a barrier's participation operand — while a select
    /// over a uniform condition stays uniform.
    #[test]
    fn select_feeding_barrier_condition() {
        let mut m = Module::new("t");
        let mut f = Function::new("k", vec![], Type::Void);
        let mut b = Builder::new(&mut f);
        let lane = b.intr(Intr::Csr(Csr::LaneId), vec![]);
        let lc = b.icmp(ICmp::Eq, lane, Val::ci(0));
        let s = b.select(lc, Val::ci(1), Val::ci(2));
        let uc = b.icmp(ICmp::Eq, Val::ci(1), Val::ci(1));
        let s2 = b.select(uc, Val::ci(1), Val::ci(2));
        b.intr(Intr::Barrier, vec![Val::ci(0), s]);
        b.ret(None);
        let fid = m.add_func(f);
        let u = analyze(&m, fid, &opts_all(), &VortexTti);
        assert!(u.val_div(s), "select over a divergent condition is divergent");
        assert!(!u.val_div(s2), "select over a uniform condition is uniform");
    }

    /// Loads from the kernel argument block are uniform under Uni-HW only.
    #[test]
    fn arg_block_loads() {
        let mut m = Module::new("t");
        let g = m.add_global(Global {
            name: "__args".into(),
            space: AddrSpace::Const,
            size: 16,
            align: 4,
            init: None,
        });
        let mut f = Function::new("k", vec![], Type::Void);
        let l;
        {
            let mut b = Builder::new(&mut f);
            l = b.load(Val::G(g), Type::I32);
            b.ret(None);
        }
        let fid = m.add_func(f);
        let hw = analyze(
            &m,
            fid,
            &UniformityOptions {
                uni_hw: true,
                ..Default::default()
            },
            &VortexTti,
        );
        assert!(!hw.val_div(l));
        let base = analyze(&m, fid, &UniformityOptions::default(), &VortexTti);
        assert!(base.val_div(l));
    }
}
