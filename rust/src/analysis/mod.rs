//! Centralized SIMT-aware analyses (paper §4.3.1).
//!
//! The middle-end owns all divergence reasoning so it can be reused across
//! Vortex variants and other open GPUs — the paper's core design decision.
//! The entry point is [`uniformity::analyze`], seeded through the
//! [`tti::TargetDivergenceInfo`] trait (the analogue of LLVM's TTI
//! `isAlwaysUniform` / `isSourceOfDivergence` hooks) and refined by the
//! annotation analysis and the call-graph function-argument analysis
//! (Algorithm 1, [`func_args`]).

pub mod callgraph;
pub mod func_args;
pub mod graphdom;
pub mod tti;
pub mod uniformity;

/// Which analysis refinements are enabled — the evaluation ladder of
/// paper §5.2 (Figures 7/8).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct UniformityOptions {
    /// Uni-HW: hardware-structure always-uniform values (machine CSRs,
    /// custom CSRs such as core_id/warp_id, loads from the uniform
    /// argument block in constant memory).
    pub uni_hw: bool,
    /// Uni-Ann: honour `uniform` qualifiers, `vortex.uniform` metadata and
    /// the intrinsic/stack-slot annotation reasoning.
    pub uni_ann: bool,
    /// Uni-Func: Algorithm-1 interprocedural argument/return refinement.
    pub uni_func: bool,
}

impl UniformityOptions {
    pub fn all() -> UniformityOptions {
        UniformityOptions {
            uni_hw: true,
            uni_ann: true,
            uni_func: true,
        }
    }
}
