//! Module call graph and reverse-post-order traversal, used by the
//! function-argument analysis (paper Algorithm 1: "build the call graph and
//! run our function-level analysis in reverse post-order").

use crate::ir::{FuncId, InstKind, Module};

#[derive(Debug)]
pub struct CallGraph {
    /// callees[f] = functions called from f (deduped).
    pub callees: Vec<Vec<FuncId>>,
    /// callers[f] = functions calling f (deduped).
    pub callers: Vec<Vec<FuncId>>,
}

impl CallGraph {
    pub fn build(m: &Module) -> CallGraph {
        let n = m.funcs.len();
        let mut callees: Vec<Vec<FuncId>> = vec![vec![]; n];
        let mut callers: Vec<Vec<FuncId>> = vec![vec![]; n];
        for (fi, f) in m.funcs.iter().enumerate() {
            for inst in f.insts.iter().filter(|i| !i.dead) {
                if let InstKind::Call { callee, .. } = &inst.kind {
                    let from = FuncId(fi as u32);
                    if !callees[fi].contains(callee) {
                        callees[fi].push(*callee);
                    }
                    if !callers[callee.idx()].contains(&from) {
                        callers[callee.idx()].push(from);
                    }
                }
            }
        }
        CallGraph { callees, callers }
    }

    /// All call sites in the module calling `target`:
    /// (caller, inst index within caller).
    pub fn call_sites(m: &Module, target: FuncId) -> Vec<(FuncId, crate::ir::InstId)> {
        let mut out = vec![];
        for (fi, f) in m.funcs.iter().enumerate() {
            for (ii, inst) in f.insts.iter().enumerate() {
                if inst.dead {
                    continue;
                }
                if let InstKind::Call { callee, .. } = &inst.kind {
                    if *callee == target {
                        out.push((FuncId(fi as u32), crate::ir::InstId(ii as u32)));
                    }
                }
            }
        }
        out
    }

    /// Reverse post-order from the given roots (kernels / external
    /// functions): callers are visited before callees, so argument
    /// uniformity flows top-down in one sweep.
    pub fn rpo_from(&self, roots: &[FuncId]) -> Vec<FuncId> {
        let n = self.callees.len();
        let mut visited = vec![false; n];
        let mut post: Vec<FuncId> = vec![];
        for &r in roots {
            if visited[r.idx()] {
                continue;
            }
            let mut stack: Vec<(FuncId, usize)> = vec![(r, 0)];
            visited[r.idx()] = true;
            while let Some((f, i)) = stack.pop() {
                let cs = &self.callees[f.idx()];
                if i < cs.len() {
                    stack.push((f, i + 1));
                    let c = cs[i];
                    if !visited[c.idx()] {
                        visited[c.idx()] = true;
                        stack.push((c, 0));
                    }
                } else {
                    post.push(f);
                }
            }
        }
        post.reverse();
        post
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Builder, Function, Linkage, Type, Val};

    fn mk_module() -> Module {
        // k (kernel) calls a; a calls b.
        let mut m = Module::new("t");
        let mut b_fn = Function::new("b", vec![], Type::I32);
        {
            let mut bb = Builder::new(&mut b_fn);
            bb.ret(Some(Val::ci(1)));
        }
        let b_id = m.add_func(b_fn);
        let mut a_fn = Function::new("a", vec![], Type::I32);
        {
            let mut bb = Builder::new(&mut a_fn);
            let v = bb.call(b_id, vec![], Type::I32);
            bb.ret(Some(v));
        }
        let a_id = m.add_func(a_fn);
        let mut k_fn = Function::new("k", vec![], Type::Void);
        k_fn.is_kernel = true;
        k_fn.linkage = Linkage::External;
        {
            let mut bb = Builder::new(&mut k_fn);
            let _ = bb.call(a_id, vec![], Type::I32);
            bb.ret(None);
        }
        m.add_func(k_fn);
        m
    }

    #[test]
    fn builds_edges_and_rpo() {
        let m = mk_module();
        let cg = CallGraph::build(&m);
        let k = m.find_func("k").unwrap();
        let a = m.find_func("a").unwrap();
        let b = m.find_func("b").unwrap();
        assert_eq!(cg.callees[k.idx()], vec![a]);
        assert_eq!(cg.callees[a.idx()], vec![b]);
        assert_eq!(cg.callers[b.idx()], vec![a]);
        let order = cg.rpo_from(&[k]);
        assert_eq!(order, vec![k, a, b]);
        assert_eq!(CallGraph::call_sites(&m, b).len(), 1);
    }
}
