//! Target Transformation Info for divergence (paper §4.3.1).
//!
//! LLVM's uniformity analysis is seeded through the TTI hooks
//! `isSourceOfDivergence` and `isAlwaysUniform`; RISC-V, being CPU-born,
//! implements neither. VOLT extends the RISC-V TTI with the *divergence
//! tracker*: lane identifiers and atomic results are divergence sources,
//! machine-level and custom CSRs are always uniform. We reproduce that
//! interface as a trait so alternative open-GPU targets (paper §6.1:
//! Ventus-style vector RISC-V, e-GPU, …) can plug in their own seeds.

use super::UniformityOptions;
use crate::ir::{Csr, Function, InstData, InstKind, Intr, WorkItem};

pub trait TargetDivergenceInfo {
    /// The value produced by `inst` differs across lanes regardless of its
    /// operands (a divergence *seed*).
    fn is_source_of_divergence(
        &self,
        f: &Function,
        inst: &InstData,
        opts: &UniformityOptions,
    ) -> bool;

    /// The value produced by `inst` is identical across lanes regardless of
    /// its operands (an always-uniform seed that *overrides* operand
    /// divergence, e.g. warp votes).
    fn is_always_uniform(&self, f: &Function, inst: &InstData, opts: &UniformityOptions) -> bool;
}

/// The Vortex divergence tracker.
pub struct VortexTti;

impl TargetDivergenceInfo for VortexTti {
    fn is_source_of_divergence(
        &self,
        _f: &Function,
        inst: &InstData,
        opts: &UniformityOptions,
    ) -> bool {
        match &inst.kind {
            InstKind::Intr { intr, .. } => match intr {
                // The lane id is the canonical divergence source.
                Intr::Csr(Csr::LaneId) => true,
                // Work-item ids embed the lane id.
                Intr::WorkItem(WorkItem::GlobalId | WorkItem::LocalId) => true,
                // Atomic results differ per lane by definition (each lane
                // observes a different order) — divergence tracker rule 2.
                Intr::Atomic(_) | Intr::AtomicCas => true,
                // Shuffle reads another lane's value — per-lane result.
                Intr::Shfl => true,
                // Group-level queries are warp-uniform only when the
                // hardware mapping guarantees a warp never spans groups —
                // that is a property of the Vortex dispatcher, modeled by
                // the Uni-HW ladder step.
                Intr::WorkItem(_) => !opts.uni_hw,
                // CSRs other than LaneId are handled by is_always_uniform;
                // without Uni-HW they are conservatively divergent.
                Intr::Csr(_) => !opts.uni_hw,
                _ => false,
            },
            // Per-thread stack addresses differ per lane on Vortex
            // (thread-indexed private memory).
            InstKind::Alloca { .. } => true,
            _ => false,
        }
    }

    fn is_always_uniform(&self, _f: &Function, inst: &InstData, opts: &UniformityOptions) -> bool {
        match &inst.kind {
            InstKind::Intr { intr, .. } => match intr {
                // Warp votes/ballots broadcast one value to all lanes.
                Intr::VoteAll | Intr::VoteAny | Intr::Ballot | Intr::Mask => true,
                // Machine-level CSRs (num_threads/num_warps/…) and custom
                // user-level CSRs (core_id/warp_id) are uniform across the
                // warp — divergence-tracker always-uniform rule, gated on
                // the Uni-HW ladder step.
                Intr::Csr(c) => opts.uni_hw && !matches!(c, Csr::LaneId),
                Intr::WorkItem(w) => {
                    opts.uni_hw
                        && matches!(
                            w,
                            WorkItem::GroupId
                                | WorkItem::LocalSize
                                | WorkItem::GlobalSize
                                | WorkItem::NumGroups
                        )
                }
                _ => false,
            },
            _ => false,
        }
    }
}

/// A pessimistic TTI with no Vortex knowledge — what stock LLVM RISC-V
/// provides (paper: "the llvm-riscv back-end does not consider branch
/// divergence"). Everything non-constant is treated as divergent. Used to
/// quantify what the divergence tracker buys.
pub struct NullTti;

impl TargetDivergenceInfo for NullTti {
    fn is_source_of_divergence(
        &self,
        _f: &Function,
        _inst: &InstData,
        _opts: &UniformityOptions,
    ) -> bool {
        true
    }
    fn is_always_uniform(&self, _f: &Function, _inst: &InstData, _opts: &UniformityOptions) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Builder, Type, Val};

    #[test]
    fn lane_id_divergent_csr_uniform_under_hw() {
        let mut f = Function::new("t", vec![], Type::Void);
        let (lane, wid);
        {
            let mut b = Builder::new(&mut f);
            lane = b.intr(Intr::Csr(Csr::LaneId), vec![]);
            wid = b.intr(Intr::Csr(Csr::WarpId), vec![]);
            b.ret(None);
        }
        let tti = VortexTti;
        let base = UniformityOptions::default();
        let hw = UniformityOptions {
            uni_hw: true,
            ..Default::default()
        };
        let (lane_i, wid_i) = match (lane, wid) {
            (Val::Inst(a), Val::Inst(b)) => (a, b),
            _ => panic!(),
        };
        assert!(tti.is_source_of_divergence(&f, f.inst(lane_i), &base));
        assert!(tti.is_source_of_divergence(&f, f.inst(lane_i), &hw));
        assert!(!tti.is_always_uniform(&f, f.inst(lane_i), &hw));
        // warp_id: divergent at base, uniform under Uni-HW.
        assert!(tti.is_source_of_divergence(&f, f.inst(wid_i), &base));
        assert!(tti.is_always_uniform(&f, f.inst(wid_i), &hw));
    }

    #[test]
    fn votes_always_uniform() {
        let mut f = Function::new("t", vec![], Type::Void);
        let v;
        {
            let mut b = Builder::new(&mut f);
            let lane = b.intr(Intr::Csr(Csr::LaneId), vec![]);
            let c = b.icmp(crate::ir::ICmp::Eq, lane, Val::ci(0));
            v = b.intr(Intr::VoteAny, vec![c]);
            b.ret(None);
        }
        let tti = VortexTti;
        if let Val::Inst(vi) = v {
            assert!(tti.is_always_uniform(&f, f.inst(vi), &UniformityOptions::default()));
        }
    }
}
