//! `volt` — CLI for the VOLT reproduction: compile kernels, run the
//! benchmark suite on the SimX-style simulator, and regenerate the
//! paper's figures/tables.
//!
//! (The build environment is offline, so argument parsing is hand-rolled
//! rather than clap.)

use volt::backend::emit::SharedMemMapping;
use volt::coordinator::{benchmarks, experiments, report};
use volt::driver::{Session, VoltOptions};
use volt::frontend::Dialect;
use volt::runtime::LaunchPolicy;
use volt::sim::{FaultPlan, SimConfig};
use volt::target::TargetDesc;
use volt::transform::OptLevel;

fn usage() -> ! {
    eprintln!(
        "usage: volt <command> [options]

commands:
  compile <file> [--cuda] [--opt LEVEL] [--target T] [--asm] [--ir]
                 [--cache-dir DIR]                       compile a kernel file
                                                         (--cache-dir adds a
                                                         persistent, corruption-
                                                         safe compile cache)
  run <benchmark> [--opt LEVEL] [--target T] [--sw-warp] [--smem-global]
                  [--no-fast-forward] [--sanitize]       run a registry benchmark
                  [--inject SPEC] [--retries N]          (prints sim throughput;
                  [--backoff CYCLES] [--cache-dir DIR]   --no-fast-forward disables
                  [--threads N] [--no-jit]               the idle-cycle skip;
                                                         --no-jit the trace-caching
                                                         warp JIT (docs/SIMJIT.md) —
                                                         both bit-identical knobs;
                                                         --sanitize enables the
                                                         shadow-memory sanitizer;
                                                         --inject arms deterministic
                                                         faults, --retries/--backoff
                                                         set the launch recovery
                                                         policy, --cache-dir the
                                                         persistent compile cache;
                                                         --threads steps cores on a
                                                         host worker pool, results
                                                         bit-identical to 1 thread)
  serve <manifest> [--devices N] [--opt LEVEL] [--retries N]
        [--backoff CYCLES] [--cache-dir DIR]             batched compile+launch
        [--cache-max BYTES] [--queue-cap N]              service over N simulated
        [--seed S] [--json FILE] [--threads N]           devices (docs/SERVING.md;
                                                         --threads drains the batch
                                                         on a worker pool, report
                                                         identical to 1 thread)
  serve --synthetic COUNT [same options]                 --synthetic runs the seeded
                                                         mixed workload instead of
                                                         a manifest file
  check <benchmark|file> [--cuda] [--block X,Y,Z] [--json]
                                                         static SIMT verification:
                                                         barrier divergence, shared-
                                                         memory races, bounds
  check --sweep [--json FILE]                            check every registry kernel
                                                         (must be clean) and the
                                                         buggy corpus (must fire)
  prof <benchmark> [--opt LEVEL] [--top N] [--annotate] [--trace FILE]
                                                         profile a benchmark: stall
                                                         breakdown + hot source lines
  prof --sweep [--opt LEVEL] [--json FILE]               profile all kernels
                                                         (BENCH_profile.json)
  targets                                                list built-in targets
  targets --sweep [--opt LEVEL] [--json FILE]            validate every kernel on
                                                         every built-in target
  validate [--levels L1,L2,...]                          run + check the whole suite
  list                                                   list registry benchmarks
  figures --fig 7|8|9|10 [--only a,b] [--csv FILE]       regenerate a paper figure
  figures --compile-time                                 compile-time overhead table
  figures --table1                                       per-stage LoC summary

LEVEL: base | uni-hw | uni-ann | uni-func | zicond | recon | o3 (default: recon)
T: vortex | vortex-min (default: vortex)
SPEC: ';'-separated faults — flip@CYCLE[:BIT] | trap@CYCLE[:PC] |
      memtrap@CYCLE[:PC] | stuckbar@CYCLE | seed@SEED[:N[:HORIZON]]"
    );
    std::process::exit(2);
}

fn parse_target(args: &[String]) -> TargetDesc {
    match opt_val(args, "--target") {
        None => TargetDesc::vortex(),
        Some(name) => TargetDesc::by_name(&name).unwrap_or_else(|| {
            eprintln!(
                "unknown target '{name}' (built-in: {})",
                TargetDesc::BUILTIN_NAMES.join(", ")
            );
            std::process::exit(2);
        }),
    }
}

fn parse_level(s: &str) -> OptLevel {
    // One spelling table for the whole CLI: the serve manifest parser
    // owns it (`opt=` fields there must match `--opt` here).
    volt::serve::parse_opt(s).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    })
}

fn flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn opt_val(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

/// Flags that consume the following token as their value (across all
/// commands, so skipping is uniform).
const VALUED: &[&str] = &[
    "--opt", "--target", "--cache-dir", "--cache-max", "--retries", "--backoff", "--inject",
    "--devices", "--queue-cap", "--seed", "--synthetic", "--json", "--top", "--trace", "--block",
    "--levels", "--fig", "--only", "--csv", "--threads",
];

const COMPILE_FLAGS: &[&str] = &["--cuda", "--opt", "--target", "--asm", "--ir", "--cache-dir"];
const RUN_FLAGS: &[&str] = &[
    "--opt",
    "--target",
    "--sw-warp",
    "--smem-global",
    "--no-fast-forward",
    "--no-jit",
    "--sanitize",
    "--inject",
    "--retries",
    "--backoff",
    "--cache-dir",
    "--threads",
];
const SERVE_FLAGS: &[&str] = &[
    "--synthetic",
    "--devices",
    "--opt",
    "--retries",
    "--backoff",
    "--cache-dir",
    "--cache-max",
    "--queue-cap",
    "--seed",
    "--json",
    "--threads",
];

/// Reject any `--flag` the command does not understand (a typo'd
/// `--retires 2` must not silently run without retries). Values of
/// valued flags are skipped, so a file named `--weird` still works as
/// e.g. `--json --weird`.
fn reject_unknown_flags(args: &[String], allowed: &[&str]) -> Result<(), String> {
    let mut i = 0;
    while i < args.len() {
        let a = args[i].as_str();
        if a.starts_with("--") {
            if !allowed.contains(&a) {
                return Err(format!("unknown flag '{a}' (allowed: {})", allowed.join(" ")));
            }
            if VALUED.contains(&a) {
                i += 2;
                continue;
            }
        }
        i += 1;
    }
    Ok(())
}

/// First argument that is neither a flag nor a valued flag's value.
fn first_positional(args: &[String]) -> Option<&String> {
    let mut i = 0;
    while i < args.len() {
        let a = args[i].as_str();
        if a.starts_with("--") {
            i += if VALUED.contains(&a) { 2 } else { 1 };
            continue;
        }
        return Some(&args[i]);
    }
    None
}

/// The options `compile`, `run`, and `serve` share, parsed in one place
/// so the spellings and defaults cannot drift between commands.
struct CommonOpts {
    level: Option<OptLevel>,
    target: TargetDesc,
    cache_dir: Option<std::path::PathBuf>,
    retries: u32,
    backoff: u64,
    inject: Option<FaultPlan>,
    /// Host worker threads (`run`: cores per cycle; `serve`: batch
    /// drain). 1 = sequential, 0 = available parallelism.
    threads: usize,
}

fn parse_common(args: &[String]) -> Result<CommonOpts, String> {
    let level = match opt_val(args, "--opt") {
        Some(s) => Some(volt::serve::parse_opt(&s)?),
        None => None,
    };
    let retries = match opt_val(args, "--retries") {
        Some(s) => s.parse().map_err(|_| format!("--retries: bad count '{s}'"))?,
        None => 0,
    };
    let backoff = match opt_val(args, "--backoff") {
        Some(s) => s.parse().map_err(|_| format!("--backoff: bad cycle count '{s}'"))?,
        None => 0,
    };
    let inject = match opt_val(args, "--inject") {
        Some(spec) => Some(FaultPlan::parse(&spec).map_err(|e| format!("--inject: {e}"))?),
        None => None,
    };
    let threads = match opt_val(args, "--threads") {
        Some(s) => s.parse().map_err(|_| format!("--threads: bad count '{s}'"))?,
        None => 1,
    };
    Ok(CommonOpts {
        level,
        target: parse_target(args),
        cache_dir: opt_val(args, "--cache-dir").map(std::path::PathBuf::from),
        retries,
        backoff,
        inject,
        threads,
    })
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let rest = &args[1..];
    let result = match cmd.as_str() {
        "compile" => cmd_compile(rest),
        "run" => cmd_run(rest),
        "serve" => cmd_serve(rest),
        "check" => cmd_check(rest),
        "prof" => cmd_prof(rest),
        "targets" => cmd_targets(rest),
        "validate" => cmd_validate(rest),
        "list" => cmd_list(),
        "figures" => cmd_figures(rest),
        _ => {
            usage();
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn cmd_compile(args: &[String]) -> Result<(), String> {
    reject_unknown_flags(args, COMPILE_FLAGS)?;
    let file = first_positional(args).ok_or("compile: missing file")?;
    let src = std::fs::read_to_string(file).map_err(|e| format!("{file}: {e}"))?;
    let dialect = if flag(args, "--cuda") || file.ends_with(".cu") {
        Dialect::Cuda
    } else {
        Dialect::OpenCL
    };
    let common = parse_common(args)?;
    let level = common.level.unwrap_or(OptLevel::Recon);
    // The builder derives the profile's geometry and warp lowering.
    let opts = VoltOptions::builder()
        .dialect(dialect)
        .opt_level(level)
        .target_desc(common.target)
        .build()
        .map_err(|e| e.to_string())?;
    if flag(args, "--ir") {
        // Dump middle-end IR.
        let (mut m, _infos) =
            volt::frontend::compile_kernels(&src, &opts.frontend()).map_err(|e| e.to_string())?;
        volt::transform::run_middle_end(&mut m, &opts.opt_config());
        print!("{}", volt::ir::printer::print_module(&m));
        return Ok(());
    }
    let session = match &common.cache_dir {
        Some(dir) => Session::with_disk_cache(opts, dir, 0),
        None => Session::new(opts),
    };
    let out = session.compile(&src)?;
    let names: Vec<&str> = out.kernel_names();
    println!(
        "compiled {} kernel(s) [{}] for {}, {} instructions, {:.2} ms (frontend {:.2} / middle {:.2} / backend {:.2})",
        out.kernels.len(),
        names.join(", "),
        out.image.target,
        out.image.code.len(),
        out.timings.total_ms(),
        out.timings.frontend_ms,
        out.timings.middle_ms,
        out.timings.backend_ms
    );
    println!(
        "divergence management: {} splits, {} divergent loops",
        out.middle.total_splits(),
        out.middle.total_pred_loops()
    );
    for k in &out.kernels {
        println!(
            "  kernel {} @ pc {} ({} params{}{})",
            k.name,
            k.entry_pc,
            k.params.len(),
            if k.uses_barrier { ", barriers" } else { "" },
            if k.local_mem > 0 { ", smem" } else { "" }
        );
    }
    if flag(args, "--asm") {
        print!("{}", out.image.disassemble());
    }
    if let Some(quarantined) = session.disk_quarantined() {
        let c = session.cache_stats();
        println!(
            "disk-cache: hits={} corrupt={} evicted={} quarantined={}",
            c.disk_hits, c.disk_corrupt, c.disk_evicted, quarantined
        );
    }
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<(), String> {
    reject_unknown_flags(args, RUN_FLAGS)?;
    let name = first_positional(args).ok_or("run: missing benchmark name")?;
    let b = benchmarks::find(name).ok_or(format!("unknown benchmark '{name}'"))?;
    let common = parse_common(args)?;
    let level = common.level.unwrap_or(OptLevel::Recon);
    let warp_hw = !flag(args, "--sw-warp");
    let smem = if flag(args, "--smem-global") {
        SharedMemMapping::Global
    } else {
        SharedMemMapping::Local
    };
    let target = common.target;
    let fast_forward = !flag(args, "--no-fast-forward");
    let sanitize = flag(args, "--sanitize");
    let jit = !flag(args, "--no-jit");

    // volt::resilience path: deterministic fault injection, launch-level
    // recovery, and/or the persistent compile cache.
    if common.inject.is_some() || common.retries > 0 || common.cache_dir.is_some() {
        if target.name != "vortex" {
            return Err(format!(
                "--inject/--retries/--backoff/--cache-dir are only available with the \
                 default vortex target, not --target {}",
                target.name
            ));
        }
        if flag(args, "--sw-warp") || flag(args, "--smem-global") || !fast_forward || sanitize
            || !jit
        {
            return Err(
                "--inject/--retries/--cache-dir cannot be combined with \
                 --sw-warp/--smem-global/--no-fast-forward/--sanitize/--no-jit"
                    .to_string(),
            );
        }
        if common.threads != 1 {
            // An armed fault plan keys on exact global cycles, so the
            // simulator runs its sequential engine; refuse rather than
            // silently ignore the flag.
            return Err("--threads is not available with --inject/--retries/--cache-dir \
                        (fault injection runs the sequential engine)"
                .to_string());
        }
        let plan = common.inject.unwrap_or_else(FaultPlan::none);
        let policy = LaunchPolicy {
            retries: common.retries,
            backoff_cycles: common.backoff,
            watchdog_max_cycles: None,
        };
        let (r, rep) =
            experiments::run_bench_resilient(&b, level, plan, policy, common.cache_dir.as_deref())
                .map_err(|e| e.to_string())?;
        println!("benchmark {name} @ {level:?} on vortex: PASS (resilient)");
        println!(
            "  resilience: injected={} retries={} recovered={}",
            rep.injected, rep.retries, rep.recovered
        );
        for l in &rep.fault_log {
            println!("    fault: {l}");
        }
        if common.cache_dir.is_some() {
            let c = rep.cache;
            println!(
                "  disk-cache: hits={} corrupt={} evicted={} quarantined={}",
                c.disk_hits, c.disk_corrupt, c.disk_evicted, rep.quarantined
            );
        }
        let s = &r.stats;
        println!(
            "  cycles {}  instrs {}  thread-instrs {}  IPC {:.3}",
            s.cycles,
            s.instrs,
            s.thread_instrs,
            s.ipc()
        );
        return Ok(());
    }

    let t0 = std::time::Instant::now();
    let r = if target.name == "vortex" {
        let sim = SimConfig {
            fast_forward,
            sanitize,
            jit,
            threads: common.threads,
            ..SimConfig::default()
        };
        experiments::run_bench(&b, level, warp_hw, smem, sim)?
    } else {
        // Non-default target: geometry and warp lowering follow the
        // profile (vortex-min has no hardware shfl/vote). Refuse flag
        // combinations the profile path would silently ignore;
        // --no-jit and --threads are host-side knobs, available on
        // every target.
        if flag(args, "--sw-warp") || flag(args, "--smem-global") || !fast_forward || sanitize {
            return Err(format!(
                "--sw-warp/--smem-global/--no-fast-forward/--sanitize are not configurable \
                 with --target {} (the profile determines the device configuration)",
                target.name
            ));
        }
        experiments::run_bench_on_configured(&b, &target, level, common.threads, jit)?
    };
    let wall_s = t0.elapsed().as_secs_f64();
    let s = &r.stats;
    // Report simulator throughput against run-phase wall time only —
    // subtracting the measured compile time keeps the fast-forward
    // on/off CI smoke sensitive to the simulator, not the compiler.
    let sim_wall = (wall_s - r.compile_ms / 1000.0).max(1e-9);
    println!("benchmark {name} @ {:?} on {}: PASS", level, target.name);
    println!(
        "  sim throughput: {:.0} warp-instrs/sec wall ({:.2}s sim of {:.2}s total, \
         fast-forward {}, jit {}, threads {})",
        s.instrs as f64 / sim_wall,
        sim_wall,
        wall_s,
        if fast_forward { "on" } else { "off" },
        if jit { "on" } else { "off" },
        common.threads
    );
    println!(
        "  cycles {}  instrs {}  thread-instrs {}  IPC {:.3}",
        s.cycles,
        s.instrs,
        s.thread_instrs,
        s.ipc()
    );
    println!(
        "  splits {}  joins {}  preds {}  tmc {}  barriers {}  warp-ops {}  atomics {}",
        s.splits, s.joins, s.preds, s.tmcs, s.barriers_executed, s.warp_ops, s.atomics
    );
    println!(
        "  loads {}  stores {}  mem-reqs {}  L1 {}/{}  L2 {}/{}  local {}",
        s.loads,
        s.stores,
        s.mem_requests,
        s.l1_hits,
        s.l1_hits + s.l1_misses,
        s.l2_hits,
        s.l2_hits + s.l2_misses,
        s.local_accesses
    );
    println!(
        "  compile {:.2} ms, code {} instrs ({} spill-traffic)",
        r.compile_ms, r.code_size, r.spill_insts
    );
    if sanitize {
        let reps = &s.sanitize_reports;
        if reps.is_empty() {
            println!("  sanitizer: clean (shadow local-memory tracking on)");
        } else {
            println!("  sanitizer: {} report(s)", reps.len());
            for rep in reps {
                println!(
                    "    {} at pc {} addr {:#x} (core {} warp {} lane {}{})",
                    rep.kind.name(),
                    rep.pc,
                    rep.addr,
                    rep.core,
                    rep.warp,
                    rep.lane,
                    match rep.line {
                        Some(l) => format!(", source line {l}"),
                        None => String::new(),
                    }
                );
            }
            return Err(format!("sanitizer found {} issue(s)", reps.len()));
        }
    }
    Ok(())
}

/// `volt serve`: one batch of compile+launch requests — from a manifest
/// file or the seeded synthetic workload — scheduled across N simulated
/// devices through the shared compile tier. Exit is nonzero only when a
/// request *without* injected faults fails; chaos requests exhausting
/// their retry budget are expected outcomes, not service errors.
fn cmd_serve(args: &[String]) -> Result<(), String> {
    reject_unknown_flags(args, SERVE_FLAGS)?;
    let common = parse_common(args)?;
    let default_opt = common.level.unwrap_or(OptLevel::Recon);
    let num = |name: &str, default: u64| -> Result<u64, String> {
        match opt_val(args, name) {
            Some(s) => s.parse().map_err(|_| format!("{name}: bad value '{s}'")),
            None => Ok(default),
        }
    };
    let cfg = volt::serve::ServeConfig {
        devices: num("--devices", 2)? as usize,
        retries: common.retries,
        backoff_cycles: common.backoff,
        queue_cap: num("--queue-cap", 0)? as usize,
        cache_dir: common.cache_dir,
        cache_max_bytes: num("--cache-max", 0)?,
        seed: num("--seed", 1)? as u32,
        threads: common.threads,
    };
    let rep = match opt_val(args, "--synthetic") {
        Some(n) => {
            let count: usize = n.parse().map_err(|_| format!("--synthetic: bad count '{n}'"))?;
            experiments::serve_synthetic(count, cfg)
        }
        None => {
            let path =
                first_positional(args).ok_or("serve: missing manifest file (or --synthetic N)")?;
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            let base = std::path::Path::new(path)
                .parent()
                .filter(|p| !p.as_os_str().is_empty())
                .unwrap_or_else(|| std::path::Path::new("."))
                .to_path_buf();
            let reqs = volt::serve::parse_manifest(&text, &base, default_opt)?;
            volt::serve::Service::new(cfg).run(reqs)
        }
    };
    print!("{}", rep.render_text());
    let json = rep.render_json();
    volt::prof::validate_json(&json)
        .map_err(|e| format!("internal: BENCH_serving.json invalid: {e}"))?;
    if let Some(path) = opt_val(args, "--json") {
        std::fs::write(&path, &json).map_err(|e| e.to_string())?;
        println!("wrote {path} ({} bytes, JSON validated)", json.len());
    }
    let clean = rep.clean_failures();
    if clean > 0 {
        return Err(format!(
            "serve: {clean} request(s) without injected faults failed"
        ));
    }
    Ok(())
}

/// Workgroup shape the static checker assumes for a registry benchmark.
/// Matches the launch shape the experiment drivers use: the tiled SGEMM
/// dispatches 8x8 workgroups, everything else is the Vortex default.
fn check_block_hint(name: &str) -> [u64; 3] {
    if name == "sgemm_tiled" {
        [8, 8, 1]
    } else {
        [64, 1, 1]
    }
}

fn parse_block(args: &[String]) -> Result<Option<[u64; 3]>, String> {
    let Some(s) = opt_val(args, "--block") else {
        return Ok(None);
    };
    let parts: Vec<u64> = s.split(',').filter_map(|x| x.trim().parse().ok()).collect();
    if parts.len() != 3 || parts.iter().any(|&x| x == 0) {
        return Err(format!("check: bad --block '{s}' (expected X,Y,Z, e.g. 64,1,1)"));
    }
    Ok(Some([parts[0], parts[1], parts[2]]))
}

fn cmd_check(args: &[String]) -> Result<(), String> {
    use volt::check::{check_source, render_json, render_text, CheckParams};
    let block = parse_block(args)?;
    if flag(args, "--sweep") {
        return check_sweep(args);
    }
    // First argument that is neither a flag nor --block's value names the
    // benchmark or kernel file to check.
    let mut name: Option<&String> = None;
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--block" {
            i += 2;
            continue;
        }
        if args[i].starts_with("--") {
            i += 1;
            continue;
        }
        name = Some(&args[i]);
        break;
    }
    let name = name.ok_or("check: missing benchmark/file name (or --sweep)")?;
    let (src, dialect, local_size) = match benchmarks::find(name) {
        Some(b) => (
            b.source.to_string(),
            b.dialect,
            block.unwrap_or_else(|| check_block_hint(name)),
        ),
        None => {
            let src = std::fs::read_to_string(name)
                .map_err(|e| format!("'{name}' is not a registry benchmark or readable file: {e}"))?;
            let dialect = if flag(args, "--cuda") || name.ends_with(".cu") {
                Dialect::Cuda
            } else {
                Dialect::OpenCL
            };
            (src, dialect, block.unwrap_or([64, 1, 1]))
        }
    };
    let diags = check_source(&src, dialect, &CheckParams { local_size })
        .map_err(|e| e.to_string())?;
    if flag(args, "--json") {
        let json = render_json(&diags);
        volt::prof::validate_json(&json)
            .map_err(|e| format!("internal: check json invalid: {e}"))?;
        println!("{json}");
    } else if diags.is_empty() {
        println!(
            "{name}: clean ({}x{}x{} workgroup)",
            local_size[0], local_size[1], local_size[2]
        );
    } else {
        print!("{}", render_text(&diags, &src));
    }
    if diags.is_empty() {
        Ok(())
    } else {
        Err(format!("check found {} issue(s) in {name}", diags.len()))
    }
}

/// `volt check --sweep`: every registry kernel must come back clean at its
/// launch shape, and every buggy-corpus kernel must fire exactly its
/// expected check id. Mirrors the `check_api` integration test so CI can
/// gate on the shipped binary.
fn check_sweep(args: &[String]) -> Result<(), String> {
    use volt::check::{buggy, check_source, render_json, CheckParams};
    let mut json = String::from("{\"schema\":\"volt-check-sweep/v1\",\"benches\":[");
    let mut failures = 0usize;
    for (i, b) in benchmarks::registry().iter().enumerate() {
        let local_size = check_block_hint(b.name);
        let entry = check_source(b.source, b.dialect, &CheckParams { local_size });
        let (status, findings) = match &entry {
            Ok(diags) if diags.is_empty() => ("clean".to_string(), render_json(diags)),
            Ok(diags) => {
                failures += 1;
                (format!("{} issue(s)", diags.len()), render_json(diags))
            }
            Err(e) => {
                failures += 1;
                (format!("compile error: {e}"), "[]".to_string())
            }
        };
        println!("{:>16}  {status}", b.name);
        if let Ok(diags) = &entry {
            if !diags.is_empty() {
                print!("{}", volt::check::render_text(diags, b.source));
            }
        }
        if i > 0 {
            json.push(',');
        }
        json.push_str(&format!(
            "{{\"name\":\"{}\",\"block\":[{},{},{}],\"clean\":{},\"findings\":{}}}",
            b.name,
            local_size[0],
            local_size[1],
            local_size[2],
            matches!(&entry, Ok(d) if d.is_empty()),
            findings
        ));
    }
    json.push_str("],\"buggy\":[");
    for (i, case) in buggy::all().iter().enumerate() {
        let params = CheckParams {
            local_size: case.block,
        };
        let entry = check_source(case.source, case.dialect, &params);
        let (ok, findings) = match &entry {
            Ok(diags) => (
                !diags.is_empty() && diags.iter().all(|d| d.id == case.expect),
                render_json(diags),
            ),
            Err(_) => (false, "[]".to_string()),
        };
        if !ok {
            failures += 1;
        }
        println!(
            "{:>16}  expect {:<22} {}",
            case.name,
            case.expect.id_str(),
            if ok { "fires" } else { "MISMATCH" }
        );
        if i > 0 {
            json.push(',');
        }
        json.push_str(&format!(
            "{{\"name\":\"{}\",\"expect\":\"{}\",\"ok\":{},\"findings\":{}}}",
            case.name,
            case.expect.id_str(),
            ok,
            findings
        ));
    }
    json.push_str(&format!("],\"failures\":{failures}}}"));
    volt::prof::validate_json(&json)
        .map_err(|e| format!("internal: BENCH_check.json invalid: {e}"))?;
    if let Some(path) = opt_val(args, "--json") {
        std::fs::write(&path, &json).map_err(|e| e.to_string())?;
        println!("wrote {path} ({} bytes, JSON validated)", json.len());
    }
    if failures > 0 {
        return Err(format!("check sweep: {failures} failure(s)"));
    }
    println!(
        "check sweep: {} registry kernels clean, {} buggy kernels fire as expected",
        benchmarks::registry().len(),
        buggy::all().len()
    );
    Ok(())
}

fn cmd_prof(args: &[String]) -> Result<(), String> {
    let level = opt_val(args, "--opt").map(|s| parse_level(&s)).unwrap_or(OptLevel::O3);
    if flag(args, "--sweep") {
        let rows = experiments::profile_sweep(level).map_err(|e| e.to_string())?;
        print!("{}", report::render_profile_sweep(&rows));
        let json = report::json_profile(&rows, level, "vortex");
        volt::prof::validate_json(&json)
            .map_err(|e| format!("internal: BENCH_profile.json invalid: {e}"))?;
        if let Some(path) = opt_val(args, "--json") {
            std::fs::write(&path, &json).map_err(|e| e.to_string())?;
            println!("wrote {path} ({} bytes, JSON validated)", json.len());
        }
        return Ok(());
    }
    let name = args.first().ok_or("prof: missing benchmark name (or --sweep)")?;
    let b = benchmarks::find(name).ok_or(format!("unknown benchmark '{name}'"))?;
    let top = opt_val(args, "--top")
        .and_then(|s| s.parse().ok())
        .unwrap_or(10usize);
    let (r, profiles) =
        experiments::profile_bench(&b, level).map_err(|e| e.to_string())?;
    println!(
        "benchmark {name} @ {:?}: PASS ({} launches, {} cycles total)",
        level,
        profiles.len(),
        r.stats.cycles
    );
    for p in &profiles {
        print!("{}", volt::prof::render_text(p, top));
    }
    if flag(args, "--annotate") {
        // Merge launches into one listing via the hottest profile.
        if let Some(p) = profiles.iter().max_by_key(|p| p.cycles) {
            print!("{}", volt::prof::annotate_source(b.source, p));
        }
    }
    if let Some(path) = opt_val(args, "--trace") {
        let target = profiles
            .first()
            .map(|p| p.target.clone())
            .unwrap_or_else(|| "vortex".into());
        let trace = volt::prof::chrome_trace(&[], &profiles, &target);
        volt::prof::validate_json(&trace)
            .map_err(|e| format!("internal: emitted trace is invalid JSON: {e}"))?;
        std::fs::write(&path, &trace).map_err(|e| e.to_string())?;
        println!("wrote {path} ({} bytes, JSON validated)", trace.len());
    }
    Ok(())
}

fn cmd_targets(args: &[String]) -> Result<(), String> {
    if !flag(args, "--sweep") {
        for t in TargetDesc::builtins() {
            let f = t.features;
            println!(
                "{:>12}  {} cores x {} warps x {} threads  features: zicond={} shfl={} \
                 vote={} fp={}  l2={}",
                t.name,
                t.default_cores,
                t.default_warps_per_core,
                t.default_threads_per_warp,
                f.zicond,
                f.shfl,
                f.vote,
                f.fp,
                t.default_l2
            );
        }
        return Ok(());
    }
    let level = opt_val(args, "--opt").map(|s| parse_level(&s)).unwrap_or(OptLevel::Recon);
    let targets = TargetDesc::builtins();
    let rows = experiments::cross_target_sweep(&targets, level).map_err(|e| e.to_string())?;
    print!("{}", report::render_cross_target(&rows));
    let json = report::json_cross_target(&rows, level);
    volt::prof::validate_json(&json)
        .map_err(|e| format!("internal: cross-target json invalid: {e}"))?;
    if let Some(path) = opt_val(args, "--json") {
        std::fs::write(&path, &json).map_err(|e| e.to_string())?;
        println!("wrote {path} ({} bytes, JSON validated)", json.len());
    }
    Ok(())
}

fn cmd_validate(args: &[String]) -> Result<(), String> {
    let levels: Vec<OptLevel> = match opt_val(args, "--levels") {
        Some(s) => s.split(',').map(parse_level).collect(),
        None => vec![
            OptLevel::Base,
            OptLevel::UniFunc,
            OptLevel::Recon,
            OptLevel::O3,
        ],
    };
    let rows = experiments::validate_all(&levels);
    print!("{}", report::render_validation(&rows));
    let failures: usize = rows
        .iter()
        .map(|r| r.results.iter().filter(|(_, res)| res.is_err()).count())
        .sum();
    let total: usize = rows.iter().map(|r| r.results.len()).sum();
    println!("{} / {} runs passed", total - failures, total);
    if failures > 0 {
        return Err(format!("{failures} validation failures"));
    }
    Ok(())
}

fn cmd_list() -> Result<(), String> {
    for b in benchmarks::registry() {
        println!(
            "{:>14}  suite={:<9} dialect={:?}{}{}",
            b.name,
            b.suite,
            b.dialect,
            if b.warp_feature { " warp" } else { "" },
            if b.smem { " smem" } else { "" }
        );
    }
    Ok(())
}

fn cmd_figures(args: &[String]) -> Result<(), String> {
    if flag(args, "--compile-time") {
        let rows = experiments::compile_time_sweep(3)?;
        print!("{}", report::render_compile_time(&rows));
        return Ok(());
    }
    if flag(args, "--table1") {
        print!("{}", table1());
        return Ok(());
    }
    let fig = opt_val(args, "--fig").ok_or("figures: need --fig N or --compile-time/--table1")?;
    let only: Option<Vec<String>> =
        opt_val(args, "--only").map(|s| s.split(',').map(|x| x.to_string()).collect());
    let only_refs: Option<Vec<&str>> = only
        .as_ref()
        .map(|v| v.iter().map(|s| s.as_str()).collect());
    match fig.as_str() {
        "7" | "8" => {
            let rows = experiments::ladder_sweep(only_refs.as_deref())?;
            if fig == "7" {
                print!("{}", report::render_ladder_fig7(&rows));
            } else {
                print!("{}", report::render_ladder_fig8(&rows));
            }
            if let Some(path) = opt_val(args, "--csv") {
                std::fs::write(&path, report::csv_ladder(&rows)).map_err(|e| e.to_string())?;
                println!("wrote {path}");
            }
        }
        "9" => {
            let rows = experiments::isa_extension_sweep()?;
            print!("{}", report::render_fig9(&rows));
        }
        "10" => {
            let rows = experiments::memory_config_sweep()?;
            print!("{}", report::render_fig10(&rows));
        }
        _ => return Err(format!("unknown figure '{fig}'")),
    }
    Ok(())
}

/// Table 1: per-stage LoC of this implementation.
fn table1() -> String {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let count = |dirs: &[&str]| -> usize {
        let mut n = 0;
        for d in dirs {
            let p = root.join("rust/src").join(d);
            if let Ok(entries) = std::fs::read_dir(&p) {
                for e in entries.flatten() {
                    if e.path().extension().map(|x| x == "rs").unwrap_or(false) {
                        if let Ok(s) = std::fs::read_to_string(e.path()) {
                            n += s.lines().count();
                        }
                    }
                }
            }
        }
        n
    };
    let rows = [
        ("OpenCL/CUDA front-end", count(&["frontend"])),
        ("Middle-end (IR + analyses + transforms)", count(&["ir", "analysis", "transform"])),
        ("Target descriptions", count(&["target"])),
        ("Back-end (ISA table + codegen)", count(&["backend"])),
        ("SimX substrate", count(&["sim"])),
        ("Host runtime + coordinator", count(&["runtime", "coordinator"])),
    ];
    let mut out = String::from("Table 1 — per-stage implementation size (this reproduction)\n");
    for (name, loc) in rows {
        out.push_str(&format!("{name:>42}: {loc:>6} LoC\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn unknown_flags_are_rejected_per_command() {
        let e = reject_unknown_flags(&argv(&["vecadd", "--retires", "2"]), RUN_FLAGS).unwrap_err();
        assert!(e.contains("--retires"), "{e}");
        reject_unknown_flags(&argv(&["vecadd", "--retries", "2"]), RUN_FLAGS).unwrap();
        // The JIT toggle is in the run allowlist; typos still reject.
        reject_unknown_flags(&argv(&["vecadd", "--no-jit"]), RUN_FLAGS).unwrap();
        assert!(reject_unknown_flags(&argv(&["vecadd", "--nojit"]), RUN_FLAGS).is_err());
        assert!(reject_unknown_flags(&argv(&["saxpy.cl", "--no-jit"]), COMPILE_FLAGS).is_err());
        // Valued flags swallow their value, so a file named like a flag
        // still parses: `--json --weird` is a filename, not a flag.
        reject_unknown_flags(
            &argv(&["--json", "--weird", "--synthetic", "5"]),
            SERVE_FLAGS,
        )
        .unwrap();
        // A run-only flag is a typo for compile, and vice versa.
        let inject = argv(&["k.cl", "--inject", "trap@1"]);
        assert!(reject_unknown_flags(&inject, COMPILE_FLAGS).is_err());
        assert!(reject_unknown_flags(&argv(&["m.txt", "--asm"]), SERVE_FLAGS).is_err());
    }

    #[test]
    fn shared_parser_reads_resilience_options() {
        let c = parse_common(&argv(&[
            "vecadd",
            "--opt",
            "o3",
            "--retries",
            "3",
            "--backoff",
            "64",
            "--cache-dir",
            "/tmp/x",
            "--inject",
            "trap@10",
            "--threads",
            "4",
        ]))
        .unwrap();
        assert_eq!(c.level, Some(OptLevel::O3));
        assert_eq!(c.retries, 3);
        assert_eq!(c.backoff, 64);
        assert_eq!(c.cache_dir.as_deref(), Some(std::path::Path::new("/tmp/x")));
        assert_eq!(c.inject.map(|p| p.len()), Some(1));
        assert_eq!(c.target.name, "vortex");
        assert_eq!(c.threads, 4);
        // Default is the sequential engine; 0 = available parallelism.
        assert_eq!(parse_common(&argv(&["vecadd"])).unwrap().threads, 1);
        assert_eq!(
            parse_common(&argv(&["--threads", "0"])).unwrap().threads,
            0
        );
        assert!(parse_common(&argv(&["--retries", "many"])).is_err());
        assert!(parse_common(&argv(&["--opt", "o9"])).is_err());
        assert!(parse_common(&argv(&["--inject", "bogus@"])).is_err());
        assert!(parse_common(&argv(&["--threads", "two"])).is_err());
    }

    #[test]
    fn first_positional_skips_flag_values() {
        let a = argv(&["--opt", "o3", "--cache-dir", "dir", "manifest.txt"]);
        assert_eq!(first_positional(&a).map(|s| s.as_str()), Some("manifest.txt"));
        assert_eq!(first_positional(&argv(&["--synthetic", "5"])), None);
    }
}
