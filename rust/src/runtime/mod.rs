//! Runtime layer: the VOLT host runtime (device memory, launches, the
//! Case-Study-2 host-API extensions) and the PJRT bridge that executes the
//! JAX/Pallas AOT reference artifacts used as correctness oracles.

pub mod device;
pub mod pjrt;

pub use device::{
    ArgValue, DeviceFault, DevicePtr, DeviceState, LaunchPolicy, RuntimeError, VoltDevice,
};
pub use pjrt::{default_artifacts_dir, PjrtReference};
