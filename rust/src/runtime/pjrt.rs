//! PJRT bridge: loads the JAX/Pallas AOT reference kernels
//! (`artifacts/*.hlo.txt`, produced once by `python/compile/aot.py`) and
//! executes them on the XLA CPU client.
//!
//! This is the correctness-oracle role the paper assigns to "reference CPU
//! implementations" (§5): every benchmark's device results are validated
//! against an independently-computed reference. Python never runs at this
//! point — the HLO text is the build artifact (see
//! /opt/xla-example/README.md for why text, not serialized protos).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One entry of the artifact manifest (a simple line format to keep the
/// offline build dependency-free):
/// `name=<k> file=<f.hlo.txt> in=<d0xd1,d0,...> out=<d0xd1>`
#[derive(Clone, Debug, PartialEq)]
pub struct KernelSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<Vec<usize>>,
    pub output: Vec<usize>,
}

pub fn parse_manifest(text: &str) -> Result<Vec<KernelSpec>, String> {
    let mut out = vec![];
    for line in text.lines().map(str::trim) {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut name = None;
        let mut file = None;
        let mut inputs = vec![];
        let mut output = vec![];
        for tok in line.split_whitespace() {
            let (k, v) = tok.split_once('=').ok_or(format!("bad manifest token {tok}"))?;
            match k {
                "name" => name = Some(v.to_string()),
                "file" => file = Some(v.to_string()),
                "in" => {
                    for shape in v.split(',') {
                        inputs.push(parse_shape(shape)?);
                    }
                }
                "out" => output = parse_shape(v)?,
                _ => return Err(format!("unknown manifest key {k}")),
            }
        }
        out.push(KernelSpec {
            name: name.ok_or("manifest line missing name")?,
            file: file.ok_or("manifest line missing file")?,
            inputs,
            output,
        });
    }
    Ok(out)
}

fn parse_shape(s: &str) -> Result<Vec<usize>, String> {
    if s == "scalar" {
        return Ok(vec![]);
    }
    s.split('x')
        .map(|d| d.parse().map_err(|_| format!("bad dim {d}")))
        .collect()
}

/// Reference executor over the AOT artifacts.
pub struct PjrtReference {
    client: xla::PjRtClient,
    specs: HashMap<String, KernelSpec>,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl PjrtReference {
    /// Load from the artifacts directory (expects `manifest.txt` +
    /// `*.hlo.txt`). Returns Err when artifacts are not built.
    pub fn load(dir: &Path) -> Result<PjrtReference, String> {
        let manifest_path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest_path)
            .map_err(|e| format!("no artifacts at {}: {e}", manifest_path.display()))?;
        let specs = parse_manifest(&text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| format!("pjrt cpu client: {e:?}"))?;
        let mut execs = HashMap::new();
        let mut spec_map = HashMap::new();
        for s in specs {
            let proto = xla::HloModuleProto::from_text_file(
                dir.join(&s.file).to_str().ok_or("bad path")?,
            )
            .map_err(|e| format!("load {}: {e:?}", s.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| format!("compile {}: {e:?}", s.name))?;
            execs.insert(s.name.clone(), exe);
            spec_map.insert(s.name.clone(), s);
        }
        Ok(PjrtReference {
            client,
            specs: spec_map,
            execs,
            dir: dir.to_path_buf(),
        })
    }

    pub fn kernels(&self) -> Vec<&KernelSpec> {
        self.specs.values().collect()
    }

    pub fn has(&self, name: &str) -> bool {
        self.execs.contains_key(name)
    }

    /// Execute a reference kernel on f32 inputs; shapes are validated
    /// against the manifest.
    pub fn run_f32(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<f32>, String> {
        let spec = self
            .specs
            .get(name)
            .ok_or(format!("unknown reference kernel '{name}'"))?;
        if inputs.len() != spec.inputs.len() {
            return Err(format!(
                "'{name}' expects {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            ));
        }
        let mut lits = vec![];
        for (data, shape) in inputs.iter().zip(spec.inputs.iter()) {
            let want: usize = shape.iter().product::<usize>().max(1);
            if data.len() != want {
                return Err(format!(
                    "'{name}' input size {} != shape {:?}",
                    data.len(),
                    shape
                ));
            }
            let lit = xla::Literal::vec1(data);
            let lit = if shape.is_empty() {
                lit
            } else {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims).map_err(|e| format!("reshape: {e:?}"))?
            };
            lits.push(lit);
        }
        let exe = &self.execs[name];
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| format!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| format!("fetch {name}: {e:?}"))?;
        let out = result
            .to_tuple1()
            .map_err(|e| format!("untuple {name}: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| format!("to_vec {name}: {e:?}"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }
}

/// Default artifacts directory (repo-root relative).
pub fn default_artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing() {
        let text = "\
# comment
name=matmul file=matmul.hlo.txt in=16x16,16x16 out=16x16
name=vecadd file=vecadd.hlo.txt in=64,64 out=64
name=scale file=scale.hlo.txt in=8,scalar out=8
";
        let specs = parse_manifest(text).unwrap();
        assert_eq!(specs.len(), 3);
        assert_eq!(specs[0].inputs, vec![vec![16, 16], vec![16, 16]]);
        assert_eq!(specs[1].output, vec![64]);
        assert_eq!(specs[2].inputs[1], Vec::<usize>::new());
        assert!(parse_manifest("name").is_err());
    }

    #[test]
    fn load_missing_dir_errors() {
        assert!(PjrtReference::load(Path::new("/nonexistent")).is_err());
    }
}
