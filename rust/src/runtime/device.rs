//! The VOLT host runtime (paper §4.2 host compilation + §5.4 Case Study
//! 2): device buffers, host↔device copies, deferred `memcpy_to_symbol`
//! materialization, shared-memory mapping selection, and kernel launch.
//!
//! This is the layer PoCL/CuPBoP host-API calls translate onto: a
//! `clCreateBuffer`/`cudaMalloc` becomes [`VoltDevice::malloc`], a
//! `clEnqueueNDRangeKernel`/kernel<<<>>> launch becomes
//! [`VoltDevice::launch`], and `cudaMemcpyToSymbol` becomes
//! [`VoltDevice::memcpy_to_symbol`] — buffered on the host and
//! materialized just before launch, after global addresses are resolved,
//! exactly as the paper describes.

use crate::backend::emit::ProgramImage;
use crate::prof::counters::Profiler;
use crate::prof::report::{build_profile, KernelProfile};
use crate::sim::{Gpu, SimConfig, SimError, SimStats};

/// Per-launch recovery policy (`volt::resilience` layer 2): how many
/// times a *transient* trap ([`crate::sim::TrapKind::transient`]) is
/// rolled back and retried from the pre-launch snapshot, how many
/// simulated cycles each recovery pause charges to the device's
/// accumulated ledger, and an optional per-launch watchdog override.
/// Deterministic faults (barrier deadlock, watchdog, structural errors)
/// always pass straight through — replaying them yields the same hang.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LaunchPolicy {
    /// Max rollback-and-retry attempts after a transient trap (0 = fail
    /// on the first trap, today's behavior).
    pub retries: u32,
    /// Simulated cycles charged to `total_stats` per retry (models the
    /// reset/replay pause; never perturbs per-run stats).
    pub backoff_cycles: u64,
    /// Per-launch `max_cycles` override — a tight watchdog for launches
    /// that must not hang the queue.
    pub watchdog_max_cycles: Option<u64>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DevicePtr(pub u32);

#[derive(Clone, Copy, Debug)]
pub enum ArgValue {
    I32(i32),
    U32(u32),
    F32(f32),
    Ptr(DevicePtr),
}

impl ArgValue {
    pub fn bits(self) -> u32 {
        match self {
            ArgValue::I32(v) => v as u32,
            ArgValue::U32(v) => v,
            ArgValue::F32(v) => v.to_bits(),
            ArgValue::Ptr(p) => p.0,
        }
    }
}

#[derive(Debug, Clone)]
pub enum RuntimeError {
    UnknownKernel(String),
    UnknownSymbol(String),
    BadLaunch(String),
    Sim(SimError),
    Mem(String),
    /// The device is sticky-faulted by an earlier trapped launch; every
    /// subsequent launch returns this until [`VoltDevice::reset`] (or a
    /// stream-level recover) clears it.
    Faulted { kernel: String, cause: SimError },
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::UnknownKernel(k) => write!(f, "unknown kernel '{k}'"),
            RuntimeError::UnknownSymbol(s) => write!(f, "unknown device symbol '{s}'"),
            RuntimeError::BadLaunch(m) => write!(f, "bad launch: {m}"),
            RuntimeError::Sim(e) => write!(f, "{e}"),
            RuntimeError::Mem(m) => write!(f, "memory error: {m}"),
            RuntimeError::Faulted { kernel, cause } => write!(
                f,
                "device is faulted (kernel '{kernel}' trapped: {cause}); \
                 reset() the device or recover() the stream to continue"
            ),
        }
    }
}

impl std::error::Error for RuntimeError {}

/// What faulted a device: the trapped kernel, the trap, and how many
/// attempts (1 + retries) were burned before giving up.
#[derive(Clone, Debug)]
pub struct DeviceFault {
    pub kernel: String,
    pub cause: SimError,
    pub attempts: u32,
}

/// Device health. A trapped launch moves the device to `Faulted` and it
/// stays there (sticky) until explicitly cleared — half-mutated memory
/// is never silently reused.
#[derive(Clone, Debug, Default)]
pub enum DeviceState {
    #[default]
    Ready,
    Faulted(DeviceFault),
}

/// Free-list entry for the device allocator.
#[derive(Debug, Clone, Copy)]
struct FreeBlock {
    addr: u32,
    size: u32,
}

pub struct VoltDevice {
    pub image: ProgramImage,
    pub gpu: Gpu,
    free_list: Vec<FreeBlock>,
    /// Deferred symbol writes (Case Study 2): (symbol, offset, bytes).
    pending_symbols: Vec<(String, u32, Vec<u8>)>,
    /// Accumulated stats over all launches.
    pub total_stats: SimStats,
    pub launches: u32,
    /// When set, every launch runs under the `volt::prof` profiler and
    /// appends a [`KernelProfile`] to `profiles`. Profiling is a pure
    /// observer: cycle counts and results are bit-identical either way.
    pub profiling: bool,
    /// Per-launch profiles, in launch order (only when `profiling`).
    pub profiles: Vec<KernelProfile>,
    /// Default recovery policy applied by [`VoltDevice::launch`] — set
    /// it once and every launch (including the registry validators,
    /// which call `launch` directly) retries transient faults.
    pub policy: LaunchPolicy,
    /// Take a pre-launch snapshot on *every* launch, so even a launch
    /// with no retry budget rolls memory back on a trap. Off by default
    /// (the snapshot copies the heap — a wall-clock cost benches don't
    /// want); streams turn it on for their devices. A snapshot is always
    /// taken when `policy.retries > 0` or faults are armed, regardless.
    pub transactional: bool,
    /// Rollback-and-retry attempts performed across all launches.
    pub retries_performed: u64,
    /// Launches that trapped at least once but completed after retry.
    pub launches_recovered: u64,
    state: DeviceState,
}

impl VoltDevice {
    pub fn new(image: ProgramImage, cfg: SimConfig) -> VoltDevice {
        let gpu = Gpu::load(&image, cfg);
        VoltDevice {
            image,
            gpu,
            free_list: vec![],
            pending_symbols: vec![],
            total_stats: SimStats::default(),
            launches: 0,
            profiling: false,
            profiles: vec![],
            policy: LaunchPolicy::default(),
            transactional: false,
            retries_performed: 0,
            launches_recovered: 0,
            state: DeviceState::Ready,
        }
    }

    /// Sticky fault from an earlier trapped launch, if any.
    pub fn fault(&self) -> Option<&DeviceFault> {
        match &self.state {
            DeviceState::Faulted(f) => Some(f),
            DeviceState::Ready => None,
        }
    }

    pub fn is_faulted(&self) -> bool {
        self.fault().is_some()
    }

    /// Acknowledge a sticky fault without rebuilding the machine: the
    /// device returns to `Ready` with memory as the rollback left it
    /// (rolled back to pre-launch state when a snapshot was taken).
    /// Used by `Stream::recover`; prefer [`VoltDevice::reset`] when a
    /// known-clean machine matters more than preserved buffers.
    pub fn clear_fault(&mut self) -> Option<DeviceFault> {
        match std::mem::take(&mut self.state) {
            DeviceState::Faulted(f) => Some(f),
            DeviceState::Ready => None,
        }
    }

    /// Restore a clean machine: reload the image onto a fresh GPU
    /// (fresh memory, caches, allocator, re-armed fault plan) and clear
    /// all accumulated state. A reset device is bit-identical to a
    /// freshly constructed one (asserted in `rust/tests/resilience_api.rs`).
    pub fn reset(&mut self) {
        self.gpu = Gpu::load(&self.image, self.gpu.cfg);
        self.free_list.clear();
        self.pending_symbols.clear();
        self.total_stats = SimStats::default();
        self.launches = 0;
        self.profiles.clear();
        self.retries_performed = 0;
        self.launches_recovered = 0;
        self.state = DeviceState::Ready;
    }

    /// Drain collected per-launch profiles.
    pub fn take_profiles(&mut self) -> Vec<KernelProfile> {
        std::mem::take(&mut self.profiles)
    }

    /// Allocate device-global memory (first-fit free list over a bump
    /// allocator).
    pub fn malloc(&mut self, size: u32) -> DevicePtr {
        let size = (size + 63) & !63;
        if let Some(k) = self
            .free_list
            .iter()
            .position(|b| b.size >= size)
        {
            let b = self.free_list[k];
            if b.size > size {
                self.free_list[k] = FreeBlock {
                    addr: b.addr + size,
                    size: b.size - size,
                };
            } else {
                self.free_list.remove(k);
            }
            return DevicePtr(b.addr);
        }
        DevicePtr(self.gpu.alloc(size))
    }

    pub fn free(&mut self, ptr: DevicePtr, size: u32) {
        self.free_list.push(FreeBlock {
            addr: ptr.0,
            size: (size + 63) & !63,
        });
    }

    pub fn memcpy_h2d(&mut self, dst: DevicePtr, bytes: &[u8]) -> Result<(), RuntimeError> {
        self.gpu
            .mem
            .write_bytes(dst.0, bytes)
            .map_err(|e| RuntimeError::Mem(format!("h2d fault at {:#x}", e.addr)))
    }

    pub fn memcpy_d2h(&self, src: DevicePtr, len: usize) -> Result<Vec<u8>, RuntimeError> {
        self.gpu
            .mem
            .read_bytes(src.0, len)
            .map_err(|e| RuntimeError::Mem(format!("d2h fault at {:#x}", e.addr)))
    }

    pub fn write_f32(&mut self, dst: DevicePtr, vals: &[f32]) -> Result<(), RuntimeError> {
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_bits().to_le_bytes()).collect();
        self.memcpy_h2d(dst, &bytes)
    }

    pub fn read_f32(&self, src: DevicePtr, n: usize) -> Result<Vec<f32>, RuntimeError> {
        let b = self.memcpy_d2h(src, n * 4)?;
        Ok(b.chunks(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }

    pub fn write_u32s(&mut self, dst: DevicePtr, vals: &[u32]) -> Result<(), RuntimeError> {
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.memcpy_h2d(dst, &bytes)
    }

    pub fn read_u32s(&self, src: DevicePtr, n: usize) -> Result<Vec<u32>, RuntimeError> {
        let b = self.memcpy_d2h(src, n * 4)?;
        Ok(b.chunks(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// `cudaMemcpyToSymbol`: buffered now, materialized at the next launch
    /// once device addresses are final (paper §5.4).
    pub fn memcpy_to_symbol(
        &mut self,
        symbol: &str,
        bytes: &[u8],
        offset: u32,
    ) -> Result<(), RuntimeError> {
        if !self.image.global_addr.contains_key(symbol) {
            return Err(RuntimeError::UnknownSymbol(symbol.to_string()));
        }
        if let Some(msg) = self.image.symbol_write_error(symbol, offset, bytes.len()) {
            return Err(RuntimeError::Mem(msg));
        }
        self.pending_symbols
            .push((symbol.to_string(), offset, bytes.to_vec()));
        Ok(())
    }

    /// Number of symbol writes still buffered (observable deferral).
    pub fn pending_symbol_writes(&self) -> usize {
        self.pending_symbols.len()
    }

    /// Launch a kernel by (source) name under the device's default
    /// [`LaunchPolicy`].
    pub fn launch(
        &mut self,
        kernel: &str,
        grid: [u32; 3],
        block: [u32; 3],
        args: &[ArgValue],
    ) -> Result<SimStats, RuntimeError> {
        let policy = self.policy;
        self.launch_with_policy(kernel, grid, block, args, policy)
    }

    /// [`VoltDevice::launch`] with an explicit per-launch policy.
    ///
    /// The launch is transactional when a snapshot is in play (always
    /// when `policy.retries > 0`, faults are armed, or
    /// [`VoltDevice::transactional`] is set): deferred symbol writes and
    /// the argument block are committed first, a snapshot of everything
    /// the run can mutate is taken, and on a trap the machine is rolled
    /// back — so a retry replays deterministically from identical state,
    /// and a final failure leaves memory pre-launch rather than
    /// half-mutated. A trap that survives the retry budget (or any
    /// deterministic trap) moves the device to sticky
    /// [`DeviceState::Faulted`].
    pub fn launch_with_policy(
        &mut self,
        kernel: &str,
        grid: [u32; 3],
        block: [u32; 3],
        args: &[ArgValue],
        policy: LaunchPolicy,
    ) -> Result<SimStats, RuntimeError> {
        if let DeviceState::Faulted(f) = &self.state {
            return Err(RuntimeError::Faulted {
                kernel: f.kernel.clone(),
                cause: f.cause.clone(),
            });
        }
        let entry_name = format!("__main_{kernel}");
        let entry = *self
            .image
            .func_entries
            .get(&entry_name)
            .ok_or_else(|| RuntimeError::UnknownKernel(kernel.to_string()))?;
        // Validate geometry.
        let bsize: u64 = block.iter().map(|&b| b as u64).product();
        if bsize == 0 || grid.iter().any(|&g| g == 0) {
            return Err(RuntimeError::BadLaunch("zero-sized launch".into()));
        }
        let nt = self.gpu.cfg.threads_per_warp as u64;
        let wpb = bsize.div_ceil(nt);
        if wpb > self.gpu.cfg.warps_per_core as u64 {
            return Err(RuntimeError::BadLaunch(format!(
                "block of {bsize} threads needs {wpb} warps, core has {}",
                self.gpu.cfg.warps_per_core
            )));
        }
        // Materialize deferred symbol writes.
        for (sym, off, bytes) in std::mem::take(&mut self.pending_symbols) {
            let base = self.image.global_addr[&sym];
            self.gpu
                .mem
                .write_bytes(base + off, &bytes)
                .map_err(|e| RuntimeError::Mem(format!("symbol write fault at {:#x}", e.addr)))?;
        }
        // Argument block.
        let a = self.image.args_addr;
        let mut words: Vec<u32> = grid.to_vec();
        words.extend(block);
        words.push(entry);
        words.extend(args.iter().map(|v| v.bits()));
        for (i, w) in words.iter().enumerate() {
            self.gpu
                .mem
                .write_u32(a + 4 * i as u32, *w)
                .map_err(|e| RuntimeError::Mem(format!("args fault at {:#x}", e.addr)))?;
        }
        // Transactional snapshot: only taken when something can use it
        // (retry budget, armed fault plan, or the stream-level promise)
        // — launches without any of those keep today's zero-copy path.
        let snap = (self.transactional || policy.retries > 0 || self.gpu.faults.pending() > 0)
            .then(|| self.gpu.snapshot());
        let saved_max = self.gpu.cfg.max_cycles;
        if let Some(w) = policy.watchdog_max_cycles {
            self.gpu.cfg.max_cycles = w;
        }
        self.gpu.label = kernel.to_string();
        let mut attempt: u32 = 0;
        let outcome = loop {
            let run = if self.profiling {
                let mut prof =
                    Profiler::new(self.image.code.len(), self.gpu.cfg.num_cores as usize);
                self.gpu
                    .run_profiled(Some(&mut prof))
                    .map(|stats| (stats, Some(prof)))
            } else {
                self.gpu.run().map(|stats| (stats, None))
            };
            match run {
                Ok(ok) => break Ok(ok),
                Err(e) => {
                    // Roll back everything the trapped run mutated.
                    if let Some(s) = snap.as_ref() {
                        self.gpu.restore(s);
                    }
                    if e.kind.transient() && attempt < policy.retries && snap.is_some() {
                        attempt += 1;
                        self.retries_performed += 1;
                        // The recovery pause is modeled time: charged to
                        // the accumulated ledger, never to per-run stats.
                        self.total_stats.cycles += policy.backoff_cycles;
                        continue;
                    }
                    break Err(e);
                }
            }
        };
        self.gpu.cfg.max_cycles = saved_max;
        match outcome {
            Ok((stats, prof)) => {
                if attempt > 0 {
                    self.launches_recovered += 1;
                }
                if let Some(prof) = prof {
                    self.profiles.push(build_profile(
                        kernel,
                        &self.image,
                        &self.gpu.cfg,
                        &stats,
                        &prof,
                        self.total_stats.cycles,
                    ));
                }
                self.launches += 1;
                accumulate(&mut self.total_stats, &stats);
                Ok(stats)
            }
            Err(e) => {
                self.state = DeviceState::Faulted(DeviceFault {
                    kernel: kernel.to_string(),
                    cause: e.clone(),
                    attempts: attempt + 1,
                });
                Err(RuntimeError::Sim(e))
            }
        }
    }
}

fn accumulate(t: &mut SimStats, s: &SimStats) {
    t.cycles += s.cycles;
    t.instrs += s.instrs;
    t.thread_instrs += s.thread_instrs;
    t.splits += s.splits;
    t.joins += s.joins;
    t.preds += s.preds;
    t.tmcs += s.tmcs;
    t.barriers_executed += s.barriers_executed;
    t.warp_ops += s.warp_ops;
    t.atomics += s.atomics;
    t.loads += s.loads;
    t.stores += s.stores;
    t.mem_requests += s.mem_requests;
    t.l1_hits += s.l1_hits;
    t.l1_misses += s.l1_misses;
    t.l2_hits += s.l2_hits;
    t.l2_misses += s.l2_misses;
    t.local_accesses += s.local_accesses;
    t.prints.extend(s.prints.iter().cloned());
    t.sanitize_reports.extend(s.sanitize_reports.iter().cloned());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{build_image, BackendOptions};
    use crate::frontend::{compile_kernels, FrontendOptions};
    use crate::transform::{run_middle_end, OptLevel};

    fn device(src: &str) -> VoltDevice {
        let (mut m, infos) = compile_kernels(src, &FrontendOptions::default()).unwrap();
        let mut cfg = OptLevel::Recon.config();
        cfg.verify = true;
        run_middle_end(&mut m, &cfg);
        let img = build_image(
            &m,
            &format!("__main_{}", infos[0].name),
            &BackendOptions::default(),
        )
        .unwrap();
        VoltDevice::new(img, crate::sim::SimConfig::default())
    }

    #[test]
    fn malloc_free_reuse() {
        let mut dev = device("kernel void k(global int* o) { o[0] = 1; }");
        let a = dev.malloc(100);
        let b = dev.malloc(100);
        assert_ne!(a, b);
        dev.free(a, 100);
        let c = dev.malloc(64);
        assert_eq!(c.0, a.0, "free list reuse");
    }

    #[test]
    fn launch_and_repeat_with_persistent_memory() {
        let mut dev = device(
            r#"
kernel void inc(global int* x, int n) {
    int i = get_global_id(0);
    if (i < n) x[i] = x[i] + 1;
}
"#,
        );
        let buf = dev.malloc(64 * 4);
        dev.write_u32s(buf, &[0u32; 64]).unwrap();
        for _ in 0..3 {
            dev.launch("inc", [1, 1, 1], [64, 1, 1], &[ArgValue::Ptr(buf), ArgValue::I32(64)])
                .unwrap();
        }
        assert_eq!(dev.read_u32s(buf, 64).unwrap(), vec![3u32; 64]);
        assert_eq!(dev.launches, 3);
        assert!(dev.total_stats.instrs > 0);
    }

    #[test]
    fn deferred_memcpy_to_symbol() {
        // Case Study 2: constant symbol initialized via the host API.
        let mut dev = device(
            r#"
__constant__ float coef[4] = { 0.0f, 0.0f, 0.0f, 0.0f };
kernel void apply(global float* x) {
    int i = get_global_id(0);
    x[i] = x[i] * coef[i % 4];
}
"#,
        );
        let buf = dev.malloc(8 * 4);
        dev.write_f32(buf, &[1.0; 8]).unwrap();
        let coefs: Vec<u8> = [2.0f32, 3.0, 4.0, 5.0]
            .iter()
            .flat_map(|v| v.to_bits().to_le_bytes())
            .collect();
        dev.memcpy_to_symbol("coef", &coefs, 0).unwrap();
        // The write is deferred until launch.
        assert_eq!(dev.pending_symbol_writes(), 1);
        dev.launch("apply", [1, 1, 1], [8, 1, 1], &[ArgValue::Ptr(buf)])
            .unwrap();
        assert_eq!(dev.pending_symbol_writes(), 0);
        assert_eq!(
            dev.read_f32(buf, 8).unwrap(),
            vec![2.0, 3.0, 4.0, 5.0, 2.0, 3.0, 4.0, 5.0]
        );
        assert!(dev.memcpy_to_symbol("nosuch", &[0], 0).is_err());
    }

    #[test]
    fn trap_sticks_until_reset() {
        // A store through a null pointer traps; the device goes sticky
        // Faulted (typed), and reset() restores a working machine.
        let mut dev = device("kernel void k(global int* o) { o[0] = 1; }");
        let e = dev
            .launch("k", [1, 1, 1], [1, 1, 1], &[ArgValue::Ptr(DevicePtr(0))])
            .unwrap_err();
        assert!(matches!(e, RuntimeError::Sim(_)), "{e}");
        assert!(dev.is_faulted());
        let f = dev.fault().unwrap();
        assert_eq!(f.kernel, "k");
        assert_eq!(f.attempts, 1);
        // Sticky: even a valid launch is refused with the original cause.
        let good = dev.malloc(64);
        let e2 = dev
            .launch("k", [1, 1, 1], [1, 1, 1], &[ArgValue::Ptr(good)])
            .unwrap_err();
        assert!(matches!(e2, RuntimeError::Faulted { .. }), "{e2}");
        assert!(e2.to_string().contains("reset()"), "{e2}");
        dev.reset();
        assert!(!dev.is_faulted());
        let good = dev.malloc(64);
        dev.launch("k", [1, 1, 1], [1, 1, 1], &[ArgValue::Ptr(good)])
            .unwrap();
        assert_eq!(dev.read_u32s(good, 1).unwrap(), vec![1]);
    }

    #[test]
    fn transient_injected_faults_retry_to_success() {
        use crate::sim::{FaultKind, FaultPlan};
        let src = r#"
kernel void inc(global int* x, int n) {
    int i = get_global_id(0);
    if (i < n) x[i] = x[i] + 1;
}
"#;
        let build = |retries: u32| {
            let (mut m, infos) = compile_kernels(src, &FrontendOptions::default()).unwrap();
            let mut cfg = OptLevel::Recon.config();
            cfg.verify = true;
            run_middle_end(&mut m, &cfg);
            let img = build_image(
                &m,
                &format!("__main_{}", infos[0].name),
                &BackendOptions::default(),
            )
            .unwrap();
            let sim = crate::sim::SimConfig {
                faults: FaultPlan::none()
                    .with(0, FaultKind::IllegalTrap { pc: None })
                    .with(0, FaultKind::MemTrap { pc: None }),
                ..crate::sim::SimConfig::default()
            };
            let mut dev = VoltDevice::new(img, sim);
            dev.policy = LaunchPolicy {
                retries,
                backoff_cycles: 50,
                watchdog_max_cycles: None,
            };
            dev
        };
        // Two scheduled transient faults: retries=2 absorbs both exactly.
        let mut dev = build(2);
        let buf = dev.malloc(64 * 4);
        dev.write_u32s(buf, &[7u32; 64]).unwrap();
        dev.launch("inc", [1, 1, 1], [64, 1, 1], &[ArgValue::Ptr(buf), ArgValue::I32(64)])
            .unwrap();
        assert_eq!(dev.read_u32s(buf, 64).unwrap(), vec![8u32; 64]);
        assert_eq!(dev.retries_performed, 2);
        assert_eq!(dev.launches_recovered, 1);
        assert!(dev.total_stats.cycles >= 100, "backoff not charged");
        // retries=1 burns the budget on the first fault and fails on the
        // second — "succeeds exactly at retries >= fault count".
        let mut dev = build(1);
        let buf = dev.malloc(64 * 4);
        dev.write_u32s(buf, &[7u32; 64]).unwrap();
        let e = dev
            .launch("inc", [1, 1, 1], [64, 1, 1], &[ArgValue::Ptr(buf), ArgValue::I32(64)])
            .unwrap_err();
        assert!(matches!(e, RuntimeError::Sim(ref s) if s.injected), "{e}");
        assert!(dev.is_faulted());
        assert_eq!(dev.fault().unwrap().attempts, 2);
        // The rollback left the inputs pre-launch (transactional).
        dev.clear_fault();
        assert_eq!(dev.read_u32s(buf, 64).unwrap(), vec![7u32; 64]);
    }

    #[test]
    fn launch_validation() {
        let mut dev = device("kernel void k(global int* o) { o[0] = 1; }");
        let b = dev.malloc(4);
        let err = dev.launch(
            "k",
            [1, 1, 1],
            [4096, 1, 1],
            &[ArgValue::Ptr(b)],
        );
        assert!(matches!(err, Err(RuntimeError::BadLaunch(_))));
        let err2 = dev.launch("nope", [1, 1, 1], [1, 1, 1], &[]);
        assert!(matches!(err2, Err(RuntimeError::UnknownKernel(_))));
    }
}
