//! PC→source mapping over the line table the backend links into every
//! [`ProgramImage`] (`pc_loc`), plus the aggregations the reports need:
//! per-line cycle totals and executed-PC coverage.

use crate::backend::emit::ProgramImage;
use crate::ir::Loc;
use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct SourceMap {
    /// Per-PC source location (index == PC); `None` over crt0 and any
    /// function compiled without locations.
    pub pc_loc: Vec<Option<Loc>>,
    /// PCs below this are runtime startup (crt0), not compiled source.
    pub crt0_len: u32,
    /// (entry pc, function name), sorted by entry pc.
    funcs: Vec<(u32, String)>,
}

impl SourceMap {
    pub fn from_image(img: &ProgramImage) -> SourceMap {
        let mut funcs: Vec<(u32, String)> = img
            .func_entries
            .iter()
            .map(|(n, &pc)| (pc, n.clone()))
            .collect();
        funcs.sort();
        SourceMap {
            pc_loc: img.pc_loc.clone(),
            crt0_len: img.crt0_len,
            funcs,
        }
    }

    pub fn loc(&self, pc: u32) -> Option<Loc> {
        self.pc_loc.get(pc as usize).copied().flatten()
    }

    /// crt0 startup code (not attributable to source).
    pub fn is_runtime(&self, pc: u32) -> bool {
        pc < self.crt0_len
    }

    /// Name of the linked function containing `pc`.
    pub fn func_of(&self, pc: u32) -> Option<&str> {
        if self.is_runtime(pc) {
            return None;
        }
        let mut best: Option<&str> = None;
        for (entry, name) in &self.funcs {
            if *entry <= pc {
                best = Some(name.as_str());
            } else {
                break;
            }
        }
        best
    }

    /// Aggregate per-PC cycles into per-source-line totals, sorted by
    /// descending cycles (then ascending line for determinism).
    pub fn line_cycles(&self, pc_cycles: &[u64]) -> Vec<(u32, u64)> {
        let mut by_line: HashMap<u32, u64> = HashMap::new();
        for (pc, &cyc) in pc_cycles.iter().enumerate() {
            if cyc == 0 {
                continue;
            }
            if let Some(loc) = self.loc(pc as u32) {
                *by_line.entry(loc.line).or_insert(0) += cyc;
            }
        }
        let mut rows: Vec<(u32, u64)> = by_line.into_iter().collect();
        rows.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        rows
    }

    /// Executed-PC line coverage: `(mapped, executed)` over distinct PCs
    /// with at least one issue, crt0 excluded (startup code is runtime,
    /// not source). The acceptance bar is mapped/executed >= 0.9.
    pub fn coverage(&self, pc_issues: &[u64]) -> (u64, u64) {
        let mut mapped = 0u64;
        let mut executed = 0u64;
        for (pc, &n) in pc_issues.iter().enumerate() {
            if n == 0 || self.is_runtime(pc as u32) {
                continue;
            }
            executed += 1;
            if self.loc(pc as u32).is_some() {
                mapped += 1;
            }
        }
        (mapped, executed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn map() -> SourceMap {
        SourceMap {
            pc_loc: vec![
                None,                  // 0: crt0
                None,                  // 1: crt0
                Some(Loc::line(10)),   // 2
                Some(Loc::line(10)),   // 3
                Some(Loc::line(12)),   // 4
                None,                  // 5: unlocated body pc
            ],
            crt0_len: 2,
            funcs: vec![(2, "__main_k".into())],
        }
    }

    #[test]
    fn line_aggregation_and_coverage() {
        let m = map();
        let pc_cycles = [5u64, 0, 3, 4, 9, 2];
        let rows = m.line_cycles(&pc_cycles);
        assert_eq!(rows, vec![(12, 9), (10, 7)]);
        // Executed everywhere: pcs 0,2,3,4,5 (pc1 never issued); crt0
        // pc0 excluded → executed = 4, mapped = 3.
        let pc_issues = [1u64, 0, 1, 1, 1, 1];
        assert_eq!(m.coverage(&pc_issues), (3, 4));
        assert_eq!(m.func_of(3), Some("__main_k"));
        assert_eq!(m.func_of(1), None);
        assert!(m.is_runtime(0) && !m.is_runtime(2));
    }
}
