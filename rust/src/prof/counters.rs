//! The in-simulator profiler sink: per-PC and per-core cycle accounting.
//!
//! [`Profiler`] is handed to [`crate::sim::Gpu::run_profiled`] and fed
//! once per simulated cycle per core. It is strictly write-only from the
//! simulator's point of view — nothing in the timing model reads it — so
//! a profiled run is cycle-for-cycle identical to an unprofiled one.
//!
//! Accounting invariant (tested): for every core,
//! `issue_cycles + stalls.iter().sum() == SimStats::cycles`.

/// Why a core could not issue on a given cycle. One reason per core per
/// stalled cycle, chosen deterministically (the warp closest to ready is
/// the bottleneck; ties broken by warp index).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum StallReason {
    /// No active warp on the core (retired or not yet spawned).
    NoActiveWarp = 0,
    /// Waiting on a non-memory functional unit (ALU/MUL/DIV/FPU/SFU
    /// latency) — the scoreboard would hold the issue slot.
    Scoreboard = 1,
    /// Every active warp is parked at a workgroup barrier.
    Barrier = 2,
    /// Waiting on the memory system (L1 miss, L2, DRAM, atomics).
    Memory = 3,
    /// Waiting after a divergence-management op (vx_split / vx_join /
    /// vx_pred / vx_tmc) — reconvergence overhead.
    Divergence = 4,
}

/// Number of [`StallReason`] variants (array-indexed counters).
pub const STALL_KINDS: usize = 5;

pub const STALL_NAMES: [&str; STALL_KINDS] = [
    "no-active-warp",
    "scoreboard",
    "barrier",
    "memory",
    "divergence",
];

/// Cap on stored occupancy change-samples per core (the chrome-trace
/// counter track); further changes are counted in `occupancy_dropped`
/// and the accumulators stay exact.
pub const OCCUPANCY_SAMPLE_CAP: usize = 4096;

/// Per-core cycle ledger.
#[derive(Clone, Debug, Default)]
pub struct CoreProfile {
    /// Cycles on which this core issued an instruction.
    pub issue_cycles: u64,
    /// Stalled cycles, by [`StallReason`] discriminant.
    pub stalls: [u64; STALL_KINDS],
    /// Σ over cycles of the core's active-warp count (occupancy integral).
    pub active_warp_cycles: u64,
    /// First / last cycle an instruction issued (core busy window).
    pub first_issue: Option<u64>,
    pub last_issue: u64,
    /// (cycle, active warps) recorded when the count changes, capped at
    /// [`OCCUPANCY_SAMPLE_CAP`].
    pub occupancy: Vec<(u64, u32)>,
    /// Change-samples dropped after the cap was reached.
    pub occupancy_dropped: u64,
    last_occ: Option<u32>,
}

impl CoreProfile {
    /// Total cycles this ledger accounts for.
    pub fn total(&self) -> u64 {
        self.issue_cycles + self.stalls.iter().sum::<u64>()
    }
}

/// The per-launch profiler: one instance per `Gpu::run_profiled` call.
#[derive(Clone, Debug)]
pub struct Profiler {
    /// Issue count per PC (instruction index).
    pub pc_issues: Vec<u64>,
    /// Latency-weighted cycles per PC: each issue charges the
    /// instruction's issue-to-ready cost, so long-latency memory ops
    /// surface as hot even at low issue counts.
    pub pc_cycles: Vec<u64>,
    pub cores: Vec<CoreProfile>,
}

impl Profiler {
    pub fn new(num_pcs: usize, num_cores: usize) -> Profiler {
        Profiler {
            pc_issues: vec![0; num_pcs],
            pc_cycles: vec![0; num_pcs],
            cores: vec![CoreProfile::default(); num_cores],
        }
    }

    /// One issue slot executed at `cycle`. Called once per issued
    /// instruction by both engines — including every instruction of a
    /// dispatched JIT trace burst, whose issues the simulator replays
    /// at their exact interpreter cycles ([`crate::sim::trace`]), so
    /// per-PC issue counts, latency attribution and the
    /// cycles-sum-to-total invariant hold with the JIT on or off.
    pub fn record_issue(&mut self, core: usize, pc: u32, cost: u64, cycle: u64) {
        let c = &mut self.cores[core];
        c.issue_cycles += 1;
        if c.first_issue.is_none() {
            c.first_issue = Some(cycle);
        }
        c.last_issue = cycle;
        if let Some(n) = self.pc_issues.get_mut(pc as usize) {
            *n += 1;
        }
        if let Some(n) = self.pc_cycles.get_mut(pc as usize) {
            *n += cost.max(1);
        }
    }

    pub fn record_stall(&mut self, core: usize, reason: StallReason, cycles: u64) {
        self.cores[core].stalls[reason as usize] += cycles;
    }

    pub fn record_occupancy(&mut self, core: usize, cycle: u64, active: u32, delta: u64) {
        let c = &mut self.cores[core];
        c.active_warp_cycles += active as u64 * delta;
        if c.last_occ != Some(active) {
            if c.occupancy.len() < OCCUPANCY_SAMPLE_CAP {
                c.occupancy.push((cycle, active));
            } else {
                c.occupancy_dropped += 1;
            }
            c.last_occ = Some(active);
        }
    }
}

/// Whole-device stall breakdown aggregated over cores. `total()` equals
/// `cycles × num_cores` — every core accounts for every cycle.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StallBreakdown {
    pub issue: u64,
    pub no_active_warp: u64,
    pub scoreboard: u64,
    pub barrier: u64,
    pub memory: u64,
    pub divergence: u64,
}

impl StallBreakdown {
    pub fn from_cores(cores: &[CoreProfile]) -> StallBreakdown {
        let mut b = StallBreakdown::default();
        for c in cores {
            b.issue += c.issue_cycles;
            b.no_active_warp += c.stalls[StallReason::NoActiveWarp as usize];
            b.scoreboard += c.stalls[StallReason::Scoreboard as usize];
            b.barrier += c.stalls[StallReason::Barrier as usize];
            b.memory += c.stalls[StallReason::Memory as usize];
            b.divergence += c.stalls[StallReason::Divergence as usize];
        }
        b
    }

    pub fn total(&self) -> u64 {
        self.issue
            + self.no_active_warp
            + self.scoreboard
            + self.barrier
            + self.memory
            + self.divergence
    }

    pub fn add(&mut self, o: &StallBreakdown) {
        self.issue += o.issue;
        self.no_active_warp += o.no_active_warp;
        self.scoreboard += o.scoreboard;
        self.barrier += o.barrier;
        self.memory += o.memory;
        self.divergence += o.divergence;
    }

    /// (label, cycles) pairs in display order, stall categories only.
    pub fn stall_rows(&self) -> [(&'static str, u64); STALL_KINDS] {
        [
            ("memory", self.memory),
            ("scoreboard", self.scoreboard),
            ("barrier", self.barrier),
            ("divergence", self.divergence),
            ("no-active-warp", self.no_active_warp),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_sums_and_occupancy_samples() {
        let mut p = Profiler::new(8, 2);
        p.record_issue(0, 3, 4, 10);
        p.record_issue(0, 3, 4, 11);
        p.record_stall(0, StallReason::Memory, 7);
        p.record_stall(1, StallReason::NoActiveWarp, 9);
        assert_eq!(p.pc_issues[3], 2);
        assert_eq!(p.pc_cycles[3], 8);
        assert_eq!(p.cores[0].total(), 9);
        assert_eq!(p.cores[1].total(), 9);
        assert_eq!(p.cores[0].first_issue, Some(10));
        assert_eq!(p.cores[0].last_issue, 11);
        // Occupancy: only changes are sampled; the integral stays exact.
        p.record_occupancy(0, 0, 4, 2);
        p.record_occupancy(0, 2, 4, 1);
        p.record_occupancy(0, 3, 2, 3);
        assert_eq!(p.cores[0].active_warp_cycles, 4 * 2 + 4 + 2 * 3);
        assert_eq!(p.cores[0].occupancy, vec![(0, 4), (3, 2)]);
        let b = StallBreakdown::from_cores(&p.cores);
        assert_eq!(b.issue, 2);
        assert_eq!(b.memory, 7);
        assert_eq!(b.no_active_warp, 9);
        assert_eq!(b.total(), 18);
    }

    #[test]
    fn out_of_range_pc_is_ignored() {
        // crt0-relative raw programs can touch any pc; the profiler must
        // not panic on images smaller than the executed range.
        let mut p = Profiler::new(2, 1);
        p.record_issue(0, 99, 1, 0);
        assert_eq!(p.cores[0].issue_cycles, 1);
    }
}
