//! [`KernelProfile`] — the per-launch profiling result — plus its text
//! report and the per-source-line annotated listing.

use super::counters::{CoreProfile, Profiler, StallBreakdown};
use super::srcmap::SourceMap;
use crate::backend::emit::ProgramImage;
use crate::ir::Loc;
use crate::sim::{SimConfig, SimStats};
use std::fmt::Write;

/// One executed PC's attribution row.
#[derive(Clone, Copy, Debug)]
pub struct PcSample {
    pub pc: u32,
    pub issues: u64,
    /// Latency-weighted cycles.
    pub cycles: u64,
    pub loc: Option<Loc>,
}

/// Everything the profiler learned about one kernel launch.
#[derive(Clone, Debug)]
pub struct KernelProfile {
    pub kernel: String,
    /// Target the profiled image was compiled for (stamped from
    /// [`ProgramImage::target`] into reports and chrome traces).
    pub target: String,
    /// Cumulative device cycles when this launch started (stream/event
    /// timeline offset for the chrome trace).
    pub start_cycles: u64,
    pub cycles: u64,
    /// Warp instructions issued.
    pub instrs: u64,
    pub ipc: f64,
    /// Average active warps per core as % of the warp table
    /// (`active_warp_cycles / (cycles × warps/core × cores)`).
    pub occupancy_pct: f64,
    /// Per-core-cycle accounting; `stalls.total() == cycles × cores`.
    pub stalls: StallBreakdown,
    pub l1_hits: u64,
    pub l1_misses: u64,
    pub l2_hits: u64,
    pub l2_misses: u64,
    pub mem_requests: u64,
    /// (source line, latency-weighted cycles), descending.
    pub hot_lines: Vec<(u32, u64)>,
    /// Latency-weighted cycles spent in regalloc spill traffic (the
    /// reload `lw`/store `sw` PCs tagged in [`ProgramImage::pc_spill`]).
    pub spill_cycles: u64,
    /// Spill cycles per source line, descending (the `--annotate`
    /// margin markers).
    pub spill_lines: Vec<(u32, u64)>,
    /// Distinct executed PCs mapping to a source line / total (crt0
    /// excluded). `mapped_pct()` is the acceptance metric.
    pub pc_mapped: u64,
    pub pc_executed: u64,
    /// Executed PCs with attribution, ascending pc (annotated listing).
    pub pc_samples: Vec<PcSample>,
    pub per_core: Vec<CoreProfile>,
    pub num_cores: u32,
    pub warps_per_core: u32,
}

impl KernelProfile {
    pub fn mapped_pct(&self) -> f64 {
        if self.pc_executed == 0 {
            100.0
        } else {
            self.pc_mapped as f64 / self.pc_executed as f64 * 100.0
        }
    }
    pub fn l1_hit_rate(&self) -> f64 {
        rate(self.l1_hits, self.l1_misses)
    }
    pub fn l2_hit_rate(&self) -> f64 {
        rate(self.l2_hits, self.l2_misses)
    }
    /// Top-N hot lines.
    pub fn hot_lines_top(&self, n: usize) -> &[(u32, u64)] {
        &self.hot_lines[..self.hot_lines.len().min(n)]
    }
    /// Total latency-weighted cycles over all mapped lines (the hot-line
    /// percentage denominator).
    pub fn line_cycles_total(&self) -> u64 {
        self.hot_lines.iter().map(|(_, c)| c).sum()
    }
}

fn rate(hits: u64, misses: u64) -> f64 {
    if hits + misses == 0 {
        0.0
    } else {
        hits as f64 / (hits + misses) as f64 * 100.0
    }
}

/// Assemble a [`KernelProfile`] from one profiled launch.
pub fn build_profile(
    kernel: &str,
    image: &ProgramImage,
    cfg: &SimConfig,
    stats: &SimStats,
    prof: &Profiler,
    start_cycles: u64,
) -> KernelProfile {
    let map = SourceMap::from_image(image);
    let stalls = StallBreakdown::from_cores(&prof.cores);
    let (pc_mapped, pc_executed) = map.coverage(&prof.pc_issues);
    let hot_lines = map.line_cycles(&prof.pc_cycles);
    // Spill traffic: the allocator-tagged PCs, total and per line.
    let mut spill_cycles = 0u64;
    let mut spill_by_line: std::collections::HashMap<u32, u64> = Default::default();
    for (pc, &cyc) in prof.pc_cycles.iter().enumerate() {
        if cyc == 0 || !image.pc_spill.get(pc).copied().unwrap_or(false) {
            continue;
        }
        spill_cycles += cyc;
        if let Some(loc) = map.loc(pc as u32) {
            *spill_by_line.entry(loc.line).or_insert(0) += cyc;
        }
    }
    let mut spill_lines: Vec<(u32, u64)> = spill_by_line.into_iter().collect();
    spill_lines.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    let mut pc_samples = vec![];
    for (pc, &n) in prof.pc_issues.iter().enumerate() {
        if n == 0 {
            continue;
        }
        pc_samples.push(PcSample {
            pc: pc as u32,
            issues: n,
            cycles: prof.pc_cycles[pc],
            loc: map.loc(pc as u32),
        });
    }
    let active: u64 = prof.cores.iter().map(|c| c.active_warp_cycles).sum();
    let denom = stats.cycles as f64
        * cfg.warps_per_core as f64
        * cfg.num_cores as f64;
    KernelProfile {
        kernel: kernel.to_string(),
        target: image.target.clone(),
        start_cycles,
        cycles: stats.cycles,
        instrs: stats.instrs,
        ipc: stats.ipc(),
        occupancy_pct: if denom > 0.0 {
            active as f64 / denom * 100.0
        } else {
            0.0
        },
        stalls,
        l1_hits: stats.l1_hits,
        l1_misses: stats.l1_misses,
        l2_hits: stats.l2_hits,
        l2_misses: stats.l2_misses,
        mem_requests: stats.mem_requests,
        hot_lines,
        spill_cycles,
        spill_lines,
        pc_mapped,
        pc_executed,
        pc_samples,
        per_core: prof.cores.clone(),
        num_cores: cfg.num_cores,
        warps_per_core: cfg.warps_per_core,
    }
}

/// Human-readable report: summary, stall breakdown (sums to
/// cycles × cores), top-N hot source lines.
pub fn render_text(p: &KernelProfile, top_n: usize) -> String {
    let mut s = String::new();
    writeln!(
        s,
        "profile: {}  [target {}]  ({} cores x {} warps)",
        p.kernel, p.target, p.num_cores, p.warps_per_core
    )
    .unwrap();
    writeln!(
        s,
        "  cycles {}  instrs {}  IPC {:.3}  occupancy {:.1}%",
        p.cycles, p.instrs, p.ipc, p.occupancy_pct
    )
    .unwrap();
    writeln!(
        s,
        "  L1 {:.1}% ({}/{})  L2 {:.1}% ({}/{})  mem-reqs {}",
        p.l1_hit_rate(),
        p.l1_hits,
        p.l1_hits + p.l1_misses,
        p.l2_hit_rate(),
        p.l2_hits,
        p.l2_hits + p.l2_misses,
        p.mem_requests
    )
    .unwrap();
    let core_cycles = p.stalls.total().max(1);
    writeln!(
        s,
        "  core-cycle breakdown (total {} = {} cycles x {} cores):",
        p.stalls.total(),
        p.cycles,
        p.num_cores
    )
    .unwrap();
    writeln!(
        s,
        "    {:>14}: {:>10}  {:5.1}%",
        "issue",
        p.stalls.issue,
        p.stalls.issue as f64 / core_cycles as f64 * 100.0
    )
    .unwrap();
    for (name, v) in p.stalls.stall_rows() {
        writeln!(
            s,
            "    {:>14}: {:>10}  {:5.1}%",
            name,
            v,
            v as f64 / core_cycles as f64 * 100.0
        )
        .unwrap();
    }
    writeln!(
        s,
        "  source mapping: {}/{} executed PCs ({:.1}%)",
        p.pc_mapped,
        p.pc_executed,
        p.mapped_pct()
    )
    .unwrap();
    let total = p.line_cycles_total().max(1);
    // Spill share only: unmapped spill PCs contribute to spill_cycles
    // but not to the per-line totals, so clamp this denominator alone —
    // the hot-line shares below keep the plain per-line total.
    let spill_denom = total.max(p.spill_cycles);
    writeln!(
        s,
        "  spill traffic: {} latency-weighted cyc ({:.1}% of line cycles) across {} lines",
        p.spill_cycles,
        p.spill_cycles as f64 / spill_denom as f64 * 100.0,
        p.spill_lines.len()
    )
    .unwrap();
    writeln!(s, "  hot lines (latency-weighted):").unwrap();
    for (line, cyc) in p.hot_lines_top(top_n) {
        writeln!(
            s,
            "    line {:>4}: {:>10} cyc  {:5.1}%",
            line,
            cyc,
            *cyc as f64 / total as f64 * 100.0
        )
        .unwrap();
    }
    s
}

/// Annotated source listing: every line of `src` prefixed with its
/// latency-weighted cycle total and share, plus a `spill` column
/// marking lines whose cycles include regalloc spill traffic.
pub fn annotate_source(src: &str, p: &KernelProfile) -> String {
    let mut per_line = std::collections::HashMap::new();
    for (line, cyc) in &p.hot_lines {
        per_line.insert(*line, *cyc);
    }
    let mut spill_line: std::collections::HashMap<u32, u64> = Default::default();
    for (line, cyc) in &p.spill_lines {
        spill_line.insert(*line, *cyc);
    }
    let total = p.line_cycles_total().max(1);
    let mut s = String::new();
    writeln!(
        s,
        "{:>10}  {:>6}  {:>9}  source ({})",
        "cycles", "%", "spill", p.kernel
    )
    .unwrap();
    for (i, text) in src.lines().enumerate() {
        let line = i as u32 + 1;
        let spill = match spill_line.get(&line) {
            Some(c) => format!("s!{c:>7}"),
            None => "         ".into(),
        };
        match per_line.get(&line) {
            Some(cyc) => writeln!(
                s,
                "{:>10}  {:>5.1}%  {spill}  {:4} | {}",
                cyc,
                *cyc as f64 / total as f64 * 100.0,
                line,
                text
            )
            .unwrap(),
            None => writeln!(s, "{:>10}  {:>6}  {spill}  {:4} | {}", "", "", line, text).unwrap(),
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prof::counters::Profiler;
    use crate::sim::SimConfig;

    fn sample_profile() -> KernelProfile {
        // Tiny synthetic image-free profile via the public builder parts.
        let mut prof = Profiler::new(4, 1);
        prof.record_issue(0, 2, 3, 0);
        prof.record_issue(0, 3, 1, 1);
        prof.record_stall(0, crate::prof::counters::StallReason::Memory, 5);
        prof.record_occupancy(0, 0, 2, 7);
        let stats = SimStats {
            cycles: 7,
            instrs: 2,
            l1_hits: 1,
            l1_misses: 1,
            ..Default::default()
        };
        let img = crate::backend::emit::ProgramImage {
            code: vec![],
            words: vec![],
            data: vec![],
            data_end: 0,
            global_addr: Default::default(),
            global_size: Default::default(),
            args_addr: 0,
            local_mem_size: 0,
            kernel: "k".into(),
            func_entries: [("__main_k".to_string(), 2u32)].into_iter().collect(),
            pc_loc: vec![None, None, Some(crate::ir::Loc::line(3)), Some(crate::ir::Loc::line(4))],
            crt0_len: 2,
            pc_spill: vec![false, false, false, true],
            target: "vortex".into(),
            addr_map: crate::target::AddressMap::vortex(),
        };
        build_profile(
            "k",
            &img,
            &SimConfig {
                num_cores: 1,
                warps_per_core: 2,
                ..SimConfig::tiny()
            },
            &stats,
            &prof,
            0,
        )
    }

    #[test]
    fn builds_and_renders() {
        let p = sample_profile();
        assert_eq!(p.stalls.total(), 7, "breakdown must sum to cycles x cores");
        assert_eq!(p.pc_executed, 2);
        assert_eq!(p.pc_mapped, 2);
        assert_eq!(p.mapped_pct(), 100.0);
        assert_eq!(p.hot_lines[0], (3, 3));
        assert!((p.occupancy_pct - 100.0).abs() < 1e-9); // 2 of 2 warps
        assert_eq!(p.target, "vortex", "profile stamped with the image's target");
        // Spill visibility: pc 3 is tagged spill traffic on line 4.
        assert_eq!(p.spill_cycles, 1);
        assert_eq!(p.spill_lines, vec![(4, 1)]);
        let txt = render_text(&p, 5);
        assert!(txt.contains("target vortex"));
        assert!(txt.contains("core-cycle breakdown"));
        assert!(txt.contains("memory"));
        assert!(txt.contains("line    3"));
        assert!(txt.contains("spill traffic: 1 "));
        let annotated = annotate_source("a\nb\nc\nd\n", &p);
        assert!(annotated.lines().count() >= 5);
        assert!(annotated.contains("   3 | c"));
        let spill_row = annotated
            .lines()
            .find(|l| l.ends_with("   4 | d"))
            .expect("line 4 in listing");
        assert!(spill_row.contains("s!"), "spill marker missing: {spill_row}");
    }
}
