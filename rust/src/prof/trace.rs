//! chrome://tracing export and a dependency-free JSON parser used to
//! validate every emitted trace (the offline build has no serde).
//!
//! Timeline model: 1 simulated cycle = 1 microsecond of trace time.
//! Tracks (tid) on pid 0:
//! * tid 0 — the stream: one complete slice (`ph:X`) per executed
//!   command, reusing the [`Event`] cycle stamps (copies are host-side
//!   and show as zero-duration slices).
//! * tid 1+c — core `c`: one busy slice per profiled launch spanning the
//!   core's first to last issue, plus a `warps.core{c}` counter track
//!   (`ph:C`) sampled from the occupancy change-log.

use super::report::KernelProfile;
use crate::driver::stream::{CommandKind, Event};
use std::fmt::Write;

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => {
                write!(out, "\\u{:04x}", c as u32).unwrap();
            }
            c => out.push(c),
        }
    }
    out
}

fn kind_cat(k: CommandKind) -> &'static str {
    match k {
        CommandKind::H2D => "h2d",
        CommandKind::D2H => "d2h",
        CommandKind::Launch => "launch",
        CommandKind::SymbolWrite => "symbol",
        CommandKind::Free => "free",
    }
}

/// Build a chrome://tracing JSON document from a stream's command events
/// and/or per-launch profiles. Either slice may be empty: `volt prof`
/// passes device profiles with no stream events (launch slices are then
/// synthesized from the profiles themselves). `target` names the machine
/// the traced image was compiled for; it is stamped into the trace's
/// `otherData` metadata and a `ph:M` process label so per-target
/// artifacts stay distinguishable.
pub fn chrome_trace(events: &[Event], profiles: &[KernelProfile], target: &str) -> String {
    let mut ev: Vec<String> = vec![];
    ev.push(format!(
        "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":0,\
         \"args\":{{\"name\":\"volt:{}\"}}}}",
        esc(target),
    ));
    let meta = |tid: u32, label: &str| {
        format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":{},\
             \"args\":{{\"name\":\"{}\"}}}}",
            tid,
            esc(label),
        )
    };
    ev.push(meta(0, "stream"));
    for e in events {
        ev.push(format!(
            "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"pid\":0,\"tid\":0,\
             \"ts\":{},\"dur\":{},\"args\":{{\"instrs\":{}}}}}",
            esc(&e.label),
            kind_cat(e.kind),
            e.start_cycles,
            e.end_cycles - e.start_cycles,
            e.instrs
        ));
    }
    if events.is_empty() {
        // Device-only profiling: synthesize the launch slices.
        for p in profiles {
            ev.push(format!(
                "{{\"name\":\"{}\",\"cat\":\"launch\",\"ph\":\"X\",\"pid\":0,\"tid\":0,\
                 \"ts\":{},\"dur\":{},\"args\":{{\"instrs\":{}}}}}",
                esc(&p.kernel),
                p.start_cycles,
                p.cycles,
                p.instrs
            ));
        }
    }
    let num_cores = profiles.iter().map(|p| p.num_cores).max().unwrap_or(0);
    for c in 0..num_cores {
        ev.push(meta(1 + c, &format!("core{c}")));
    }
    for p in profiles {
        for (c, core) in p.per_core.iter().enumerate() {
            let tid = 1 + c as u32;
            if let Some(first) = core.first_issue {
                ev.push(format!(
                    "{{\"name\":\"{}\",\"cat\":\"core\",\"ph\":\"X\",\"pid\":0,\
                     \"tid\":{},\"ts\":{},\"dur\":{},\"args\":{{\"issue_cycles\":{}}}}}",
                    esc(&p.kernel),
                    tid,
                    p.start_cycles + first,
                    core.last_issue.saturating_sub(first) + 1,
                    core.issue_cycles
                ));
            }
            for (cycle, warps) in &core.occupancy {
                ev.push(format!(
                    "{{\"name\":\"warps.core{}\",\"ph\":\"C\",\"pid\":0,\"tid\":{},\
                     \"ts\":{},\"args\":{{\"active\":{}}}}}",
                    c,
                    tid,
                    p.start_cycles + cycle,
                    warps
                ));
            }
        }
    }
    let mut s = format!(
        "{{\"displayTimeUnit\":\"ms\",\"otherData\":{{\"target\":\"{}\"}},\
         \"traceEvents\":[\n",
        esc(target)
    );
    for (i, e) in ev.iter().enumerate() {
        s.push_str(e);
        if i + 1 != ev.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("]}\n");
    s
}

// ---------------------------------------------------------------------------
// Minimal JSON parser (validation only — no DOM is built)
// ---------------------------------------------------------------------------

/// Parse `src` as a single JSON value (RFC 8259 subset: no surrogate
/// validation) and reject trailing garbage. Used by tests and the CLI to
/// prove emitted traces/readouts are well-formed.
pub fn validate_json(src: &str) -> Result<(), String> {
    let b: Vec<char> = src.chars().collect();
    let mut p = Json { b: &b, i: 0 };
    p.ws();
    p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at char {}", p.i));
    }
    Ok(())
}

struct Json<'a> {
    b: &'a [char],
    i: usize,
}

impl<'a> Json<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], ' ' | '\t' | '\n' | '\r')
        {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<char> {
        self.b.get(self.i).copied()
    }
    fn eat(&mut self, c: char) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{c}' at char {}", self.i))
        }
    }
    fn lit(&mut self, s: &str) -> Result<(), String> {
        for c in s.chars() {
            self.eat(c)?;
        }
        Ok(())
    }
    fn value(&mut self) -> Result<(), String> {
        match self.peek() {
            Some('{') => self.object(),
            Some('[') => self.array(),
            Some('"') => self.string(),
            Some('t') => self.lit("true"),
            Some('f') => self.lit("false"),
            Some('n') => self.lit("null"),
            Some(c) if c == '-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at char {}", self.i)),
        }
    }
    fn object(&mut self) -> Result<(), String> {
        self.eat('{')?;
        self.ws();
        if self.peek() == Some('}') {
            self.i += 1;
            return Ok(());
        }
        loop {
            self.ws();
            self.string()?;
            self.ws();
            self.eat(':')?;
            self.ws();
            self.value()?;
            self.ws();
            match self.peek() {
                Some(',') => self.i += 1,
                Some('}') => {
                    self.i += 1;
                    return Ok(());
                }
                other => return Err(format!("bad object at char {} ({other:?})", self.i)),
            }
        }
    }
    fn array(&mut self) -> Result<(), String> {
        self.eat('[')?;
        self.ws();
        if self.peek() == Some(']') {
            self.i += 1;
            return Ok(());
        }
        loop {
            self.ws();
            self.value()?;
            self.ws();
            match self.peek() {
                Some(',') => self.i += 1,
                Some(']') => {
                    self.i += 1;
                    return Ok(());
                }
                other => return Err(format!("bad array at char {} ({other:?})", self.i)),
            }
        }
    }
    fn string(&mut self) -> Result<(), String> {
        self.eat('"')?;
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some('"') => {
                    self.i += 1;
                    return Ok(());
                }
                Some('\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some('"' | '\\' | '/' | 'b' | 'f' | 'n' | 'r' | 't') => self.i += 1,
                        Some('u') => {
                            self.i += 1;
                            for _ in 0..4 {
                                match self.peek() {
                                    Some(c) if c.is_ascii_hexdigit() => self.i += 1,
                                    _ => return Err("bad \\u escape".into()),
                                }
                            }
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                }
                Some(c) if (c as u32) < 0x20 => {
                    return Err("raw control char in string".into())
                }
                Some(_) => self.i += 1,
            }
        }
    }
    fn number(&mut self) -> Result<(), String> {
        if self.peek() == Some('-') {
            self.i += 1;
        }
        let mut digits = 0;
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
            digits += 1;
        }
        if digits == 0 {
            return Err(format!("bad number at char {}", self.i));
        }
        if self.peek() == Some('.') {
            self.i += 1;
            let mut frac = 0;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
                frac += 1;
            }
            if frac == 0 {
                return Err("bad fraction".into());
            }
        }
        if matches!(self.peek(), Some('e' | 'E')) {
            self.i += 1;
            if matches!(self.peek(), Some('+' | '-')) {
                self.i += 1;
            }
            let mut exp = 0;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
                exp += 1;
            }
            if exp == 0 {
                return Err("bad exponent".into());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_parser_accepts_valid() {
        for ok in [
            "{}",
            "[]",
            "null",
            "-12.5e+3",
            "{\"a\":[1,2,{\"b\":\"x\\n\\u0041\"}],\"c\":true}",
            " { \"traceEvents\" : [ ] } ",
        ] {
            validate_json(ok).unwrap_or_else(|e| panic!("{ok}: {e}"));
        }
    }

    #[test]
    fn json_parser_rejects_invalid() {
        for bad in [
            "",
            "{",
            "[1,]",
            "{\"a\" 1}",
            "tru",
            "1.2.3",
            "\"unterminated",
            "{} garbage",
            "01e",
            "{\"a\":}",
        ] {
            assert!(validate_json(bad).is_err(), "accepted invalid: {bad}");
        }
    }

    #[test]
    fn empty_trace_is_valid_json() {
        let t = chrome_trace(&[], &[], "vortex");
        validate_json(&t).unwrap();
        assert!(t.contains("traceEvents"));
    }

    #[test]
    fn escapes_labels() {
        let e = Event {
            label: "we\"ird\\name".into(),
            kind: CommandKind::H2D,
            enqueue_cycles: 0,
            start_cycles: 0,
            end_cycles: 0,
            instrs: 0,
        };
        let t = chrome_trace(&[e], &[], "we\"ird\\target");
        validate_json(&t).unwrap();
    }

    #[test]
    fn trace_is_stamped_with_target() {
        let t = chrome_trace(&[], &[], "vortex-min");
        validate_json(&t).unwrap();
        assert!(t.contains("\"otherData\":{\"target\":\"vortex-min\"}"), "{t}");
        assert!(t.contains("volt:vortex-min"));
    }
}
