//! `volt::prof` — the cycle-attributing profiler (measurement foundation
//! for every perf PR; see `docs/PROFILING.md`).
//!
//! The paper's evaluation lives and dies on *explaining* cycle deltas:
//! SimX exists precisely so that performance differences are
//! deterministic and attributable to the compiler (§5). This subsystem
//! turns the simulator's raw determinism into attribution:
//!
//! * [`counters`] — the in-simulator [`counters::Profiler`] sink: per-PC
//!   issue/cycle accumulators, a per-core per-cycle issue-stall taxonomy
//!   (no-active-warp / scoreboard / barrier / memory / divergence) that
//!   sums exactly to the run's cycle count, and warp-occupancy
//!   accumulators. Pure observer: cycle counts are bit-identical with
//!   profiling on or off.
//! * [`srcmap`] — the PC→source mapping derived from the line table the
//!   backend links into every [`crate::backend::emit::ProgramImage`]
//!   (`pc_loc`), itself fed by the `Loc` plumbing that runs
//!   lexer → AST → IR → transforms → MIR → encoded PCs.
//! * [`report`] — [`report::KernelProfile`]: per-launch cycles, IPC,
//!   occupancy, stall breakdown, cache hit rates and hot source lines,
//!   with a text report and a per-line annotated source listing.
//! * [`trace`] — chrome://tracing JSON export (one track per core, a
//!   warp-occupancy counter track, one slice per stream command) plus a
//!   dependency-free JSON parser used to validate every emitted trace.
//!
//! Entry points: [`crate::driver::VoltOptions`]`::profiling(true)` for
//! session/stream use, [`crate::runtime::VoltDevice`]`::profiling` for
//! direct device use, `volt prof <benchmark>` on the CLI, and
//! `experiments::profile_sweep` for the whole-suite `BENCH_profile.json`.

pub mod counters;
pub mod report;
pub mod srcmap;
pub mod trace;

pub use counters::{CoreProfile, Profiler, StallBreakdown, StallReason};
pub use report::{annotate_source, build_profile, render_text, KernelProfile};
pub use srcmap::SourceMap;
pub use trace::{chrome_trace, validate_json};
