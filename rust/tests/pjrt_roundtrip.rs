//! PJRT round-trip: the JAX/Pallas AOT artifacts load, compile and
//! execute from Rust, and their results validate the simulated GPU's
//! output (the §5 "reference CPU implementation" role).
//!
//! Requires `make artifacts`; tests are skipped (with a notice) when the
//! artifacts directory has not been built.

use volt::backend::emit::BackendOptions;
use volt::coordinator::{compile_source, Rng};
use volt::frontend::FrontendOptions;
use volt::runtime::{default_artifacts_dir, ArgValue, PjrtReference, VoltDevice};
use volt::sim::SimConfig;
use volt::transform::OptLevel;

fn reference() -> Option<PjrtReference> {
    match PjrtReference::load(&default_artifacts_dir()) {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("skipping PJRT tests (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn artifacts_execute_with_known_values() {
    let Some(r) = reference() else { return };
    assert!(r.platform().to_lowercase().contains("cpu") || !r.platform().is_empty());
    // vecadd
    let a: Vec<f32> = (0..1000).map(|i| i as f32).collect();
    let b: Vec<f32> = (0..1000).map(|i| 2.0 * i as f32).collect();
    let out = r.run_f32("vecadd1000", &[a.clone(), b.clone()]).unwrap();
    for i in 0..1000 {
        assert_eq!(out[i], 3.0 * i as f32);
    }
    // matmul against a Rust-computed reference
    let mut rng = Rng(7);
    let ma: Vec<f32> = (0..256).map(|_| rng.f32_01()).collect();
    let mb: Vec<f32> = (0..256).map(|_| rng.f32_01()).collect();
    let mm = r.run_f32("matmul16", &[ma.clone(), mb.clone()]).unwrap();
    for i in 0..16 {
        for j in 0..16 {
            let want: f32 = (0..16).map(|k| ma[i * 16 + k] * mb[k * 16 + j]).sum();
            assert!(
                (mm[i * 16 + j] - want).abs() < 1e-3,
                "({i},{j}): {} vs {want}",
                mm[i * 16 + j]
            );
        }
    }
    // composed L2 graph: gemm+bias+relu is non-negative and matches.
    let bias: Vec<f32> = (0..16).map(|i| -0.5 + i as f32 * 0.05).collect();
    let g = r
        .run_f32("gemm_bias_relu16", &[ma.clone(), mb.clone(), bias.clone()])
        .unwrap();
    for i in 0..16 {
        for j in 0..16 {
            let dot: f32 = (0..16).map(|k| ma[i * 16 + k] * mb[k * 16 + j]).sum();
            let want = (dot + bias[j]).max(0.0);
            assert!((g[i * 16 + j] - want).abs() < 1e-3);
        }
    }
}

/// The mandated cross-validation: device (compiled VCL on the SIMT
/// simulator) vs the PJRT-executed Pallas reference, same inputs.
#[test]
fn device_sgemm_matches_pallas_reference() {
    let Some(r) = reference() else { return };
    let src = r#"
kernel void sgemm(global float* a, global float* b, global float* c, int n) {
    int row = get_global_id(1);
    int col = get_global_id(0);
    if (row < n && col < n) {
        float s = 0.0f;
        for (int t = 0; t < n; t++) { s += a[row * n + t] * b[t * n + col]; }
        c[row * n + col] = s;
    }
}
"#;
    let out = compile_source(
        src,
        &FrontendOptions::default(),
        OptLevel::Recon,
        &BackendOptions::default(),
    )
    .unwrap();
    let mut dev = VoltDevice::new(out.image.clone(), SimConfig::default());
    let n = 16usize;
    let mut rng = Rng(99);
    let a: Vec<f32> = (0..n * n).map(|_| rng.f32_01() * 2.0 - 1.0).collect();
    let b: Vec<f32> = (0..n * n).map(|_| rng.f32_01() * 2.0 - 1.0).collect();
    let pa = dev.malloc((n * n * 4) as u32);
    let pb = dev.malloc((n * n * 4) as u32);
    let pc = dev.malloc((n * n * 4) as u32);
    dev.write_f32(pa, &a).unwrap();
    dev.write_f32(pb, &b).unwrap();
    dev.launch(
        "sgemm",
        [2, 2, 1],
        [8, 8, 1],
        &[
            ArgValue::Ptr(pa),
            ArgValue::Ptr(pb),
            ArgValue::Ptr(pc),
            ArgValue::I32(n as i32),
        ],
    )
    .unwrap();
    let device_out = dev.read_f32(pc, n * n).unwrap();
    let pallas_out = r.run_f32("matmul16", &[a, b]).unwrap();
    for i in 0..n * n {
        assert!(
            (device_out[i] - pallas_out[i]).abs() < 1e-3,
            "elem {i}: device {} vs pallas {}",
            device_out[i],
            pallas_out[i]
        );
    }
}

#[test]
fn manifest_covers_expected_kernels() {
    let Some(r) = reference() else { return };
    for k in [
        "matmul16",
        "matmul24",
        "matmul128",
        "vecadd1000",
        "saxpy777",
        "transpose24",
        "blocksum512",
        "gemm_bias_relu16",
    ] {
        assert!(r.has(k), "missing artifact {k}");
    }
}
