//! Integration tests for the `volt::check` static SIMT verifier and the
//! simulator's shadow-memory sanitizer cross-check.
//!
//! The contract under test (ISSUE 6 acceptance criteria):
//!
//! * every registry benchmark kernel is clean at its launch shape, both
//!   through `check_source` directly and through `Session` with the
//!   checker in Deny mode on every built-in target;
//! * every `benchmarks/buggy/` kernel fires exactly its expected check
//!   id with a source-located diagnostic, and Deny mode turns that into
//!   a typed `VoltError::Validation`;
//! * the checker is pure analysis: enabling it does not change the
//!   program's cache fingerprint;
//! * the dynamic sanitizer catches every memory bug of the buggy corpus
//!   at runtime (barrier-divergence deadlocks are the static checker's
//!   alone) and is a pure observer on clean kernels.

use volt::backend::emit::SharedMemMapping;
use volt::check::{buggy, check_source, CheckId, CheckMode, CheckParams};
use volt::coordinator::{benchmarks, experiments};
use volt::driver::{compile_program, Session, VoltError, VoltOptions};
use volt::runtime::{ArgValue, VoltDevice};
use volt::sim::{SanitizeKind, SimConfig};
use volt::transform::OptLevel;

/// Workgroup shape the checker assumes per benchmark — the same shape
/// the experiment drivers dispatch (`volt check` uses the same hint).
fn block_hint(name: &str) -> [u64; 3] {
    if name == "sgemm_tiled" {
        [8, 8, 1]
    } else {
        [64, 1, 1]
    }
}

#[test]
fn every_registry_kernel_is_clean_statically() {
    for b in benchmarks::registry() {
        let params = CheckParams {
            local_size: block_hint(b.name),
        };
        let diags = check_source(b.source, b.dialect, &params)
            .unwrap_or_else(|e| panic!("{}: checker front-end error: {e}", b.name));
        assert!(
            diags.is_empty(),
            "{}: expected clean, got {:?}",
            b.name,
            diags
                .iter()
                .map(|d| (d.id.id_str(), d.kernel.as_str(), d.line()))
                .collect::<Vec<_>>()
        );
    }
}

#[test]
fn every_registry_kernel_compiles_under_deny_on_every_target() {
    // Deny mode rejects any diagnostic at compile time, so a successful
    // compile *is* the cleanliness assertion. The checker itself is
    // target-independent (it always analyzes the portable hardware-warp
    // lowering); running on both built-in targets proves the driver
    // wiring holds when the main pipeline lowers differently
    // (vortex-min compiles warp builtins through software emulation).
    for target in ["vortex", "vortex-min"] {
        for b in benchmarks::registry() {
            let hint = block_hint(b.name);
            let opts = VoltOptions::builder()
                .dialect(b.dialect)
                .target(target)
                .check(CheckMode::Deny)
                .check_local_size([hint[0] as u32, hint[1] as u32, hint[2] as u32])
                .build()
                .unwrap();
            let s = Session::new(opts);
            s.compile(b.source)
                .unwrap_or_else(|e| panic!("{target}/{}: {e}", b.name));
            assert!(
                s.last_diagnostics().is_empty(),
                "{target}/{}: diagnostics recorded on a clean kernel",
                b.name
            );
        }
    }
}

#[test]
fn buggy_corpus_fires_exactly_its_expected_ids_through_the_driver() {
    for case in buggy::all() {
        let ls = [
            case.block[0] as u32,
            case.block[1] as u32,
            case.block[2] as u32,
        ];
        // Warn: compile succeeds, diagnostics recorded on the session,
        // every diagnostic carries the expected id and a source line.
        let s = Session::new(
            VoltOptions::builder()
                .dialect(case.dialect)
                .check(CheckMode::Warn)
                .check_local_size(ls)
                .build()
                .unwrap(),
        );
        s.compile(case.source)
            .unwrap_or_else(|e| panic!("{}: warn mode must still compile: {e}", case.name));
        let diags = s.last_diagnostics();
        assert!(
            !diags.is_empty(),
            "{}: expected {} but the kernel came back clean",
            case.name,
            case.expect.id_str()
        );
        for d in diags {
            assert_eq!(
                d.id,
                case.expect,
                "{}: expected only {}, got {} ({})",
                case.name,
                case.expect.id_str(),
                d.id.id_str(),
                d.msg
            );
            assert!(
                d.line().is_some(),
                "{}: diagnostic is not source-located: {}",
                case.name,
                d.msg
            );
        }
        // Deny: typed validation error naming the check id.
        let s = Session::new(
            VoltOptions::builder()
                .dialect(case.dialect)
                .check(CheckMode::Deny)
                .check_local_size(ls)
                .build()
                .unwrap(),
        );
        let e = s.compile(case.source).unwrap_err();
        assert!(
            matches!(e, VoltError::Validation { .. }),
            "{}: expected a validation error, got {e}",
            case.name
        );
        assert!(
            e.to_string().contains(case.expect.id_str()),
            "{}: error does not name the check id: {e}",
            case.name
        );
    }
}

#[test]
fn check_mode_does_not_change_the_cache_fingerprint() {
    let src = benchmarks::find("vecadd").unwrap().source;
    let p_off = Session::new(VoltOptions::builder().build().unwrap())
        .compile(src)
        .unwrap();
    let p_checked = Session::new(
        VoltOptions::builder()
            .check(CheckMode::Warn)
            .check_local_size([8, 8, 1])
            .build()
            .unwrap(),
    )
    .compile(src)
    .unwrap();
    assert_eq!(
        p_off.fingerprint, p_checked.fingerprint,
        "the checker is pure analysis: same binary, same cache entry"
    );
}

/// Sanitizer report kinds a given static check id may legitimately
/// manifest as at runtime. A missing-barrier read-write race can also
/// surface as an uninitialized read depending on warp interleaving, but
/// the conflicting store always fires ReadWrite, so the mapping stays
/// exact.
fn expected_kinds(id: CheckId) -> &'static [SanitizeKind] {
    match id {
        CheckId::RaceWriteWrite => &[SanitizeKind::WriteWrite],
        CheckId::RaceReadWrite => &[SanitizeKind::ReadWrite],
        CheckId::RaceMayAlias => &[SanitizeKind::WriteWrite, SanitizeKind::ReadWrite],
        CheckId::BoundsLocalOob => &[SanitizeKind::OutOfBounds],
        CheckId::UninitLocalRead => &[SanitizeKind::UninitRead],
        CheckId::BarrierDivergence | CheckId::BarrierDivergentLoop => &[],
    }
}

#[test]
fn sanitizer_catches_the_buggy_corpus_at_runtime() {
    for case in buggy::all() {
        if !case.sanitizer_catchable() {
            continue;
        }
        let opts = VoltOptions::builder()
            .dialect(case.dialect)
            .build()
            .unwrap();
        let prog = compile_program(case.source, &opts)
            .unwrap_or_else(|e| panic!("{}: {e}", case.name));
        let cfg = SimConfig {
            sanitize: true,
            ..opts.device_config()
        };
        let mut dev = VoltDevice::new(prog.image.clone(), cfg);
        // Every corpus kernel has the (global T* in, global T* out)
        // signature over one 64-element workgroup.
        let n = 64usize;
        let input: Vec<f32> = (0..n).map(|i| i as f32 + 1.0).collect();
        let a = dev.malloc(n as u32 * 4);
        let b = dev.malloc(n as u32 * 4);
        dev.write_f32(a, &input).unwrap();
        dev.write_f32(b, &vec![0.0; n]).unwrap();
        let kernel = prog.kernels[0].name.clone();
        let stats = dev
            .launch(
                &kernel,
                [1, 1, 1],
                [
                    case.block[0] as u32,
                    case.block[1] as u32,
                    case.block[2] as u32,
                ],
                &[ArgValue::Ptr(a), ArgValue::Ptr(b)],
            )
            .unwrap_or_else(|e| panic!("{}: launch failed: {e}", case.name));
        let want = expected_kinds(case.expect);
        let kinds: Vec<SanitizeKind> = stats.sanitize_reports.iter().map(|r| r.kind).collect();
        assert!(
            stats
                .sanitize_reports
                .iter()
                .any(|r| want.contains(&r.kind) && r.line.is_some()),
            "{}: expected a source-located report of {:?}, got {:?}",
            case.name,
            want,
            kinds
        );
    }
}

#[test]
fn sanitizer_is_a_pure_observer_on_a_clean_benchmark() {
    let b = benchmarks::find("reduce").unwrap();
    let run = |sanitize: bool| {
        experiments::run_bench(
            &b,
            OptLevel::O3,
            true,
            SharedMemMapping::Local,
            SimConfig {
                sanitize,
                ..SimConfig::default()
            },
        )
        .unwrap()
    };
    let base = run(false);
    let san = run(true);
    // run_bench validates the benchmark's results internally, so both
    // runs already proved correctness; here we pin bit-identical timing.
    assert_eq!(base.stats.cycles, san.stats.cycles);
    assert_eq!(base.stats.instrs, san.stats.instrs);
    assert_eq!(base.stats.l1_hits, san.stats.l1_hits);
    assert_eq!(base.stats.local_accesses, san.stats.local_accesses);
    assert!(
        san.stats.sanitize_reports.is_empty(),
        "clean benchmark produced sanitizer reports: {:?}",
        san.stats.sanitize_reports
    );
}
