//! Host-runtime API integration (paper §4.2 host path + §5.4 Case Study
//! 2): multi-kernel modules with persistent device memory, deferred
//! symbol copies, allocator behaviour and launch validation.

use volt::backend::emit::BackendOptions;
use volt::coordinator::compile_source;
use volt::frontend::FrontendOptions;
use volt::runtime::{ArgValue, RuntimeError, VoltDevice};
use volt::sim::SimConfig;
use volt::transform::OptLevel;

fn device(src: &str) -> VoltDevice {
    let out = compile_source(
        src,
        &FrontendOptions::default(),
        OptLevel::Recon,
        &BackendOptions::default(),
    )
    .unwrap();
    VoltDevice::new(out.image.clone(), SimConfig::default())
}

/// Two kernels, one image: init writes, scale reads what init wrote.
#[test]
fn multi_kernel_module_shares_memory() {
    let mut dev = device(
        r#"
kernel void init(global float* x, int n) {
    int i = get_global_id(0);
    if (i < n) x[i] = (float)i;
}
kernel void scale(global float* x, float a, int n) {
    int i = get_global_id(0);
    if (i < n) x[i] = x[i] * a;
}
"#,
    );
    let n = 96u32;
    let buf = dev.malloc(n * 4);
    dev.launch("init", [1, 1, 1], [96, 1, 1], &[ArgValue::Ptr(buf), ArgValue::I32(n as i32)])
        .unwrap();
    dev.launch(
        "scale",
        [1, 1, 1],
        [96, 1, 1],
        &[ArgValue::Ptr(buf), ArgValue::F32(2.5), ArgValue::I32(n as i32)],
    )
    .unwrap();
    let got = dev.read_f32(buf, n as usize).unwrap();
    for (i, v) in got.iter().enumerate() {
        assert_eq!(*v, i as f32 * 2.5);
    }
    assert_eq!(dev.launches, 2);
}

/// cudaMemcpyToSymbol with offset into a constant table.
#[test]
fn memcpy_to_symbol_with_offset() {
    let mut dev = device(
        r#"
__constant__ float table[8] = { 0.0f, 0.0f, 0.0f, 0.0f, 0.0f, 0.0f, 0.0f, 0.0f };
kernel void k(global float* out) {
    int i = get_global_id(0);
    out[i] = table[i % 8];
}
"#,
    );
    // Overwrite entries 4..8 only.
    let bytes: Vec<u8> = [9.0f32, 8.0, 7.0, 6.0]
        .iter()
        .flat_map(|v| v.to_bits().to_le_bytes())
        .collect();
    dev.memcpy_to_symbol("table", &bytes, 16).unwrap();
    let out = dev.malloc(8 * 4);
    dev.launch("k", [1, 1, 1], [8, 1, 1], &[ArgValue::Ptr(out)]).unwrap();
    assert_eq!(
        dev.read_f32(out, 8).unwrap(),
        vec![0.0, 0.0, 0.0, 0.0, 9.0, 8.0, 7.0, 6.0]
    );
}

/// __device__ globals are writable by kernels and persist across launches.
#[test]
fn device_global_counter() {
    let mut dev = device(
        r#"
__device__ int counter[1];
kernel void bump(global int* unused) {
    unused[0] = 0;
    atomic_add(counter, 1);
}
"#,
    );
    let b = dev.malloc(4);
    for _ in 0..2 {
        dev.launch("bump", [1, 1, 1], [64, 1, 1], &[ArgValue::Ptr(b)]).unwrap();
    }
    let addr = dev.image.global_addr["counter"];
    assert_eq!(dev.gpu.mem.read_u32(addr).unwrap(), 128);
}

/// Allocator: free-list coalescing behaviour (first-fit reuse, distinct
/// live blocks).
#[test]
fn allocator_first_fit() {
    let mut dev = device("kernel void k(global int* o) { o[0] = 1; }");
    let a = dev.malloc(256);
    let b = dev.malloc(256);
    let c = dev.malloc(1024);
    assert!(a.0 < b.0 && b.0 < c.0);
    dev.free(b, 256);
    let d = dev.malloc(128);
    assert_eq!(d.0, b.0, "first fit reuses the freed block");
    let e = dev.malloc(64);
    assert_eq!(e.0, b.0 + 128, "remainder split");
}

/// Launch validation catches unknown kernels, oversized blocks, zero grids.
#[test]
fn launch_validation_errors() {
    let mut dev = device("kernel void k(global int* o) { o[0] = 1; }");
    let b = dev.malloc(4);
    assert!(matches!(
        dev.launch("nope", [1, 1, 1], [1, 1, 1], &[]),
        Err(RuntimeError::UnknownKernel(_))
    ));
    assert!(matches!(
        dev.launch("k", [0, 1, 1], [1, 1, 1], &[ArgValue::Ptr(b)]),
        Err(RuntimeError::BadLaunch(_))
    ));
    assert!(matches!(
        dev.launch("k", [1, 1, 1], [32 * 64, 1, 1], &[ArgValue::Ptr(b)]),
        Err(RuntimeError::BadLaunch(_))
    ));
    // A good launch still works afterwards.
    dev.launch("k", [1, 1, 1], [1, 1, 1], &[ArgValue::Ptr(b)]).unwrap();
    assert_eq!(dev.read_u32s(b, 1).unwrap(), vec![1]);
}

/// 2-D/3-D geometry round-trips through the dispatcher correctly.
#[test]
fn multi_dim_launch_geometry() {
    let mut dev = device(
        r#"
kernel void idx3(global int* out, int nx, int ny) {
    int x = get_global_id(0);
    int y = get_global_id(1);
    int z = get_global_id(2);
    out[(z * ny + y) * nx + x] = x + 100 * y + 10000 * z;
}
"#,
    );
    let (nx, ny, nz) = (8u32, 4u32, 2u32);
    let out = dev.malloc(nx * ny * nz * 4);
    dev.launch(
        "idx3",
        [2, 2, 2],
        [4, 2, 1],
        &[ArgValue::Ptr(out), ArgValue::I32(nx as i32), ArgValue::I32(ny as i32)],
    )
    .unwrap();
    let got = dev.read_u32s(out, (nx * ny * nz) as usize).unwrap();
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                assert_eq!(
                    got[((z * ny + y) * nx + x) as usize],
                    x + 100 * y + 10000 * z,
                    "({x},{y},{z})"
                );
            }
        }
    }
}

/// Stats accumulate across launches.
#[test]
fn stats_accumulation() {
    let mut dev = device(
        "kernel void k(global int* o, int n) { int i = get_global_id(0); if (i < n) o[i] = i; }",
    );
    let b = dev.malloc(64 * 4);
    let s1 = dev
        .launch("k", [1, 1, 1], [64, 1, 1], &[ArgValue::Ptr(b), ArgValue::I32(64)])
        .unwrap();
    let s2 = dev
        .launch("k", [1, 1, 1], [64, 1, 1], &[ArgValue::Ptr(b), ArgValue::I32(64)])
        .unwrap();
    assert_eq!(dev.total_stats.instrs, s1.instrs + s2.instrs);
    assert!(dev.total_stats.cycles >= s1.cycles + s2.cycles - 1);
}
