//! `volt::resilience` integration (ISSUE 7): typed-error stability,
//! fault-injection determinism, launch-level recovery, sticky stream
//! containment, and the corruption-safe persistent cache — all through
//! the public API alone.

use volt::driver::{Session, VoltError, VoltOptions};
use volt::runtime::{ArgValue, LaunchPolicy, RuntimeError, VoltDevice};
use volt::sim::{FaultKind, FaultPlan, FaultState, SimConfig, SimError, SimStats, TrapKind};

const INC: &str = r#"
kernel void inc(global int* x, int n) {
    int i = get_global_id(0);
    if (i < n) x[i] = x[i] + 1;
}
"#;

const BARRIER_SUM: &str = r#"
kernel void bsum(global float* in, global float* out) {
    local float buf[64];
    int l = get_local_id(0);
    buf[l] = in[l];
    barrier(0);
    out[l] = buf[63 - l];
}
"#;

fn compile(src: &str) -> (std::sync::Arc<volt::driver::Program>, SimConfig) {
    let session = Session::new(VoltOptions::builder().build().unwrap());
    let prog = session.compile(src).unwrap();
    (prog, session.options().device_config())
}

fn device_with(src: &str, faults: FaultPlan) -> VoltDevice {
    let (prog, base) = compile(src);
    let cfg = SimConfig { faults, ..base };
    VoltDevice::new(prog.image.clone(), cfg)
}

/// One inc-run: seed the buffer, launch, return (per-run stats, result).
fn run_inc(dev: &mut VoltDevice, seed: u32) -> Result<(SimStats, Vec<u32>), RuntimeError> {
    let buf = dev.malloc(64 * 4);
    dev.write_u32s(buf, &[seed; 64])?;
    let stats = dev.launch("inc", [1, 1, 1], [64, 1, 1], &[ArgValue::Ptr(buf), ArgValue::I32(64)])?;
    let out = dev.read_u32s(buf, 64)?;
    Ok((stats, out))
}

/// Every error variant the resilience surface can hand back has a stable,
/// greppable rendering and a stable `stage()` tag — logs and CI greps
/// depend on these strings.
#[test]
fn error_variant_display_is_stable() {
    let cases: Vec<(VoltError, &str, &str)> = vec![
        (
            VoltError::Frontend { line: 0, msg: "empty module".into() },
            "frontend",
            "frontend error: empty module",
        ),
        (
            VoltError::Frontend { line: 7, msg: "unknown variable".into() },
            "frontend",
            "frontend error at line 7: unknown variable",
        ),
        (
            VoltError::MiddleEnd { pass: "verify", msg: "bad ssa".into() },
            "middle-end",
            "middle-end error in pass 'verify': bad ssa",
        ),
        (
            VoltError::Runtime(RuntimeError::UnknownKernel("k".into())),
            "runtime",
            "runtime error: unknown kernel 'k'",
        ),
        (
            VoltError::Runtime(RuntimeError::UnknownSymbol("coef".into())),
            "runtime",
            "runtime error: unknown device symbol 'coef'",
        ),
        (
            VoltError::Runtime(RuntimeError::BadLaunch("zero-sized launch".into())),
            "runtime",
            "runtime error: bad launch: zero-sized launch",
        ),
        (
            VoltError::Runtime(RuntimeError::Mem("h2d fault at 0x0".into())),
            "runtime",
            "runtime error: memory error: h2d fault at 0x0",
        ),
        (
            VoltError::Runtime(RuntimeError::Sim(SimError {
                core: 1,
                warp: 2,
                pc: 12,
                msg: "injected fault: memory trap".into(),
                kind: TrapKind::MemFault,
                injected: true,
            })),
            "runtime",
            "runtime error: sim error at core 1 warp 2 pc 12: injected fault: memory trap [injected]",
        ),
        (
            VoltError::InvalidOptions { msg: "bad combo".into() },
            "options",
            "invalid options: bad combo",
        ),
        (
            VoltError::stream("transfer read before synchronize"),
            "stream",
            "stream error: transfer read before synchronize",
        ),
        (
            VoltError::Validation { msg: "mismatch at 3".into() },
            "validation",
            "validation failed: mismatch at 3",
        ),
    ];
    for (err, stage, display) in cases {
        assert_eq!(err.stage(), stage, "{err:?}");
        assert_eq!(err.to_string(), display, "{err:?}");
        // Every variant is Clone and renders identically after cloning —
        // the property the sticky stream fault relies on.
        assert_eq!(err.clone().to_string(), display);
    }
    // The sticky-device error points at both recovery paths by name.
    let faulted = RuntimeError::Faulted {
        kernel: "inc".into(),
        cause: SimError::fatal(0, 0, 0, "boom"),
    };
    let s = faulted.to_string();
    assert!(s.contains("device is faulted"), "{s}");
    assert!(s.contains("kernel 'inc'"), "{s}");
    assert!(s.contains("reset()") && s.contains("recover()"), "{s}");
}

/// Differential contract: an armed-but-never-firing plan must not
/// disturb the machine — cycles, instruction counts, and results are
/// bit-identical to a device built with no plan at all.
#[test]
fn armed_but_unfired_plan_is_bit_identical() {
    let mut plain = device_with(INC, FaultPlan::none());
    // A fault scheduled far past any reachable cycle: armed (so the
    // snapshot/guard paths are live) but never injected.
    let mut armed = device_with(
        INC,
        FaultPlan::none().with(u64::MAX / 2, FaultKind::IllegalTrap { pc: None }),
    );
    let (s1, r1) = run_inc(&mut plain, 7).unwrap();
    let (s2, r2) = run_inc(&mut armed, 7).unwrap();
    assert_eq!(r1, r2);
    assert_eq!((s1.cycles, s1.instrs, s1.loads, s1.stores), (s2.cycles, s2.instrs, s2.loads, s2.stores));
    assert_eq!(armed.gpu.faults.injected(), 0);
    assert_eq!(armed.gpu.faults.pending(), 1);
}

/// Retry-exactness: faults are device-lifetime one-shot, so a launch
/// recovers iff `retries >= scheduled transient faults` — and the run
/// that recovers produces the exact same results as an uninjected one.
#[test]
fn retry_succeeds_exactly_at_fault_count() {
    let plan = FaultPlan::none()
        .with(0, FaultKind::IllegalTrap { pc: None })
        .with(0, FaultKind::MemTrap { pc: None });

    // Reference result from a clean device.
    let (_, want) = run_inc(&mut device_with(INC, FaultPlan::none()), 7).unwrap();

    // retries = faults: recovers, results identical to the clean run.
    let mut dev = device_with(INC, plan);
    dev.policy = LaunchPolicy { retries: 2, backoff_cycles: 25, watchdog_max_cycles: None };
    let (_, got) = run_inc(&mut dev, 7).unwrap();
    assert_eq!(got, want);
    assert_eq!(dev.retries_performed, 2);
    assert_eq!(dev.launches_recovered, 1);
    assert_eq!(dev.gpu.faults.injected(), 2);
    assert_eq!(dev.gpu.faults.log.len(), 2, "{:?}", dev.gpu.faults.log);

    // retries = faults - 1: the budget runs dry and the device faults,
    // with the input rolled back to its pre-launch value.
    let mut dev = device_with(INC, plan);
    dev.policy = LaunchPolicy { retries: 1, backoff_cycles: 25, watchdog_max_cycles: None };
    let buf = dev.malloc(64 * 4);
    dev.write_u32s(buf, &[7u32; 64]).unwrap();
    let e = dev
        .launch("inc", [1, 1, 1], [64, 1, 1], &[ArgValue::Ptr(buf), ArgValue::I32(64)])
        .unwrap_err();
    assert!(matches!(e, RuntimeError::Sim(ref s) if s.injected), "{e}");
    assert!(dev.is_faulted());
    assert_eq!(dev.fault().unwrap().attempts, 2);
    dev.clear_fault();
    assert_eq!(dev.read_u32s(buf, 64).unwrap(), vec![7u32; 64], "rollback");
}

/// `reset()` restores a machine bit-identical to a freshly constructed
/// device: same allocator addresses, same per-run stats, same results —
/// even after the previous machine trapped and sticky-faulted.
#[test]
fn reset_then_rerun_is_bit_identical_to_fresh_device() {
    let (fresh_stats, fresh_out) = run_inc(&mut device_with(INC, FaultPlan::none()), 3).unwrap();

    // Poison a device: the injected trap faults it (no retry budget).
    let mut dev = device_with(INC, FaultPlan::none().with(0, FaultKind::MemTrap { pc: None }));
    let e = run_inc(&mut dev, 3).unwrap_err();
    assert!(matches!(e, RuntimeError::Sim(ref s) if s.injected), "{e}");
    assert!(dev.is_faulted());

    // reset() re-arms the fault plan too — consume it under a retry
    // budget this time, then compare the recovered run against fresh.
    dev.reset();
    assert!(!dev.is_faulted());
    assert_eq!(dev.gpu.faults.pending(), 1, "reset re-arms the plan");
    dev.policy = LaunchPolicy { retries: 1, backoff_cycles: 0, watchdog_max_cycles: None };
    let (stats, out) = run_inc(&mut dev, 3).unwrap();
    assert_eq!(out, fresh_out);
    assert_eq!((stats.cycles, stats.instrs), (fresh_stats.cycles, fresh_stats.instrs));
    assert_eq!(dev.launches, 1);
    assert_eq!(dev.launches_recovered, 1);
}

/// A failed command sticky-faults its stream with the original typed
/// cause; `recover()` hands the fault back once and restores service.
#[test]
fn stream_containment_and_recover_roundtrip() {
    let session = Session::new(VoltOptions::builder().build().unwrap());
    let prog = session.compile(INC).unwrap();
    let mut st = session.create_stream(&prog);
    st.device_mut().gpu.faults =
        FaultState::new(FaultPlan::none().with(0, FaultKind::IllegalTrap { pc: None }));

    let buf = st.malloc(64 * 4);
    st.enqueue_write_u32(buf, &[5u32; 64]).unwrap();
    st.enqueue_launch("inc", [1, 1, 1], [64, 1, 1], &[ArgValue::Ptr(buf), ArgValue::I32(64)])
        .unwrap();
    let t = st.enqueue_read_u32(buf, 64);
    let e = st.synchronize().unwrap_err();
    assert!(e.to_string().contains("[injected]"), "{e}");

    // Sticky: the same typed cause comes back from every subsequent call.
    assert!(st.is_faulted());
    let again = st.enqueue_write_u32(buf, &[1u32; 64]).unwrap_err();
    assert_eq!(again.to_string(), e.to_string());
    // The residual read was defined as Failed, naming the faulting launch.
    let read = st.take_u32(t).unwrap_err();
    assert!(read.to_string().contains("stream faulted at 'inc'"), "{read}");

    // recover() returns the fault exactly once, then the stream works —
    // and the rollback preserved the pre-launch buffer contents.
    let f = st.recover().expect("one latched fault");
    assert_eq!(f.label, "inc");
    assert!(st.recover().is_none());
    st.enqueue_launch("inc", [1, 1, 1], [64, 1, 1], &[ArgValue::Ptr(buf), ArgValue::I32(64)])
        .unwrap();
    let t2 = st.enqueue_read_u32(buf, 64);
    st.synchronize().unwrap();
    assert_eq!(st.take_u32(t2).unwrap(), vec![6u32; 64]);
}

/// The watchdog is deterministic: it passes straight through any retry
/// budget, and its trap names the kernel and dumps per-warp state. Uses
/// the runtime-only corpus kernel (statically clean, hangs at runtime).
#[test]
fn watchdog_trap_is_enriched_and_never_retried() {
    let case = volt::check::buggy::runtime_all()
        .into_iter()
        .find(|c| c.name == "watchdog_infinite_loop")
        .expect("runtime corpus entry");
    assert_eq!(case.expect_trap, "watchdog");
    let (prog, cfg) = compile(case.source);
    let mut dev = VoltDevice::new(prog.image.clone(), cfg);
    let buf = dev.malloc(64 * 4);
    dev.write_u32s(buf, &[0u32; 64]).unwrap();
    let policy = LaunchPolicy {
        retries: 3,
        backoff_cycles: 10,
        watchdog_max_cycles: Some(20_000),
    };
    let e = dev
        .launch_with_policy(
            "watchdog_infinite_loop",
            [1, 1, 1],
            [64, 1, 1],
            &[ArgValue::Ptr(buf), ArgValue::I32(64)],
            policy,
        )
        .unwrap_err();
    let RuntimeError::Sim(sim) = &e else { panic!("{e}") };
    assert_eq!(sim.kind, TrapKind::Watchdog);
    assert!(!sim.kind.transient());
    assert!(sim.msg.contains("exceeded max cycles (20000)"), "{}", sim.msg);
    assert!(sim.msg.contains("kernel 'watchdog_infinite_loop'"), "{}", sim.msg);
    assert!(sim.msg.contains("core 0 warp 0: pc"), "{}", sim.msg);
    assert_eq!(dev.retries_performed, 0, "watchdog must not be retried");
    assert!(dev.is_faulted());
}

/// A dropped barrier arrival deadlocks deterministically; the trap is
/// attributed to the injector but still refuses the retry budget — a
/// hang is a hang on replay too.
#[test]
fn stuck_barrier_deadlock_passes_through_retry() {
    let mut dev = device_with(BARRIER_SUM, FaultPlan::none().with(0, FaultKind::StuckBarrier));
    dev.policy = LaunchPolicy { retries: 5, backoff_cycles: 10, watchdog_max_cycles: None };
    let a = dev.malloc(64 * 4);
    let b = dev.malloc(64 * 4);
    dev.write_f32(a, &[1.5f32; 64]).unwrap();
    let e = dev
        .launch("bsum", [1, 1, 1], [64, 1, 1], &[ArgValue::Ptr(a), ArgValue::Ptr(b)])
        .unwrap_err();
    let RuntimeError::Sim(sim) = &e else { panic!("{e}") };
    assert_eq!(sim.kind, TrapKind::Deadlock);
    assert!(sim.injected, "deadlock must be attributed to the injector");
    assert!(sim.msg.contains("barrier deadlock"), "{}", sim.msg);
    assert!(sim.msg.contains("kernel 'bsum'"), "{}", sim.msg);
    assert_eq!(dev.retries_performed, 0, "deadlock must not be retried");

    // Proof the kernel itself is sound: a fresh device with no plan runs
    // it to completion.
    let mut ok = device_with(BARRIER_SUM, FaultPlan::none());
    let a = ok.malloc(64 * 4);
    let b = ok.malloc(64 * 4);
    ok.write_f32(a, &[1.5f32; 64]).unwrap();
    ok.launch("bsum", [1, 1, 1], [64, 1, 1], &[ArgValue::Ptr(a), ArgValue::Ptr(b)])
        .unwrap();
    assert_eq!(ok.read_f32(b, 64).unwrap(), vec![1.5f32; 64]);
}

/// The persistent cache end to end through the public API: a second
/// session hits the disk tier; a flipped byte degrades to a quarantined
/// miss and a correct recompile — never a crash, never a wrong program.
#[test]
fn disk_cache_survives_sessions_and_contains_corruption() {
    let dir = std::env::temp_dir().join(format!("volt-resilience-dc-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let opts = || VoltOptions::builder().build().unwrap();
    let (fp, words) = {
        let s1 = Session::with_disk_cache(opts(), &dir, 0);
        let p = s1.compile(INC).unwrap();
        (p.fingerprint, p.image.words.clone())
    };

    // Fresh session, same directory: served from disk, zero compiles.
    let s2 = Session::with_disk_cache(opts(), &dir, 0);
    let p2 = s2.compile(INC).unwrap();
    assert_eq!(p2.fingerprint, fp);
    assert_eq!(p2.image.words, words);
    let cs = s2.cache_stats();
    assert_eq!((cs.disk_hits, cs.misses, cs.disk_corrupt), (1, 0, 0));

    // Flip one byte in the stored entry: the next session must detect
    // it, quarantine the file, and recompile to an identical program.
    let entry = s2.disk_entry_path(fp).unwrap();
    let mut bytes = std::fs::read(&entry).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x20;
    std::fs::write(&entry, &bytes).unwrap();

    let s3 = Session::with_disk_cache(opts(), &dir, 0);
    let p3 = s3.compile(INC).unwrap();
    assert_eq!(p3.fingerprint, fp);
    assert_eq!(p3.image.words, words, "recompile must be bit-identical");
    let cs = s3.cache_stats();
    assert_eq!((cs.disk_corrupt, cs.disk_hits, cs.misses), (1, 0, 1));
    assert_eq!(s3.disk_quarantined(), Some(1));
    assert!(!entry.exists(), "corrupt entry must leave the cache dir");

    // The recompile re-stored the entry: a fourth session hits again.
    let s4 = Session::with_disk_cache(opts(), &dir, 0);
    s4.compile(INC).unwrap();
    assert_eq!(s4.cache_stats().disk_hits, 1);

    // And the cached program actually runs: correct results from a
    // device built off the disk-served image.
    let mut dev = VoltDevice::new(p2.image.clone(), s2.options().device_config());
    let (_, out) = run_inc(&mut dev, 9).unwrap();
    assert_eq!(out, vec![10u32; 64]);

    let _ = std::fs::remove_dir_all(&dir);
}
