//! `volt::serve` integration (ISSUE 8): batch determinism, per-request
//! fault isolation, compile dedup through the shared session tier,
//! admission-queue behavior, and two sessions sharing one disk-cache
//! directory — all through the public API alone.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use volt::coordinator::experiments::serve_synthetic;
use volt::driver::{fingerprint, Session, VoltOptions};
use volt::serve::{
    parse_manifest, Priority, Provenance, RequestStatus, ServeConfig, ServeRequest, Service,
};
use volt::sim::{FaultKind, FaultPlan};
use volt::transform::OptLevel;

fn tmpdir(tag: &str) -> PathBuf {
    static N: AtomicU64 = AtomicU64::new(0);
    let d = std::env::temp_dir().join(format!(
        "volt-serve-{tag}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Fixed (workload, seed, devices) must render byte-identical JSON, and
/// chaos requests must never take a clean neighbor down with them.
#[test]
fn synthetic_batch_is_deterministic_and_contains_faults() {
    let cfg = ServeConfig {
        devices: 2,
        retries: 1,
        seed: 7,
        ..ServeConfig::default()
    };
    let a = serve_synthetic(40, cfg.clone());
    let b = serve_synthetic(40, cfg);
    assert_eq!(a.render_json(), b.render_json(), "reruns must be bit-identical");
    volt::prof::validate_json(&a.render_json()).unwrap();

    assert_eq!(a.outcomes.len(), 40);
    assert_eq!(a.clean_failures(), 0, "no fault-free request may fail");
    for o in &a.outcomes {
        if o.injected == 0 {
            assert!(
                o.status.is_ok(),
                "clean request {} ({}) ended {:?}",
                o.id,
                o.label,
                o.status
            );
        }
        if o.status == RequestStatus::Faulted {
            assert!(o.injected > 0, "a Faulted outcome must have injected faults");
        }
    }
    // The seeded mix actually exercises the cache: hot repeats dedup.
    assert!(a.cache.hits > 0, "hot-repeat class must produce mem hits");
    assert!(a.cache.misses > 0);
    let (p50, p95, p99) = a.latency_percentiles();
    assert!(p50 > 0 && p50 <= p95 && p95 <= p99);
}

/// The device count changes the schedule (queueing, utilization), never
/// what each request computes or where its compile was served from.
#[test]
fn device_count_changes_schedule_not_outcomes() {
    let narrow = serve_synthetic(
        30,
        ServeConfig {
            devices: 1,
            retries: 2,
            seed: 5,
            ..ServeConfig::default()
        },
    );
    let wide = serve_synthetic(
        30,
        ServeConfig {
            devices: 4,
            retries: 2,
            seed: 5,
            ..ServeConfig::default()
        },
    );
    assert_eq!(narrow.outcomes.len(), wide.outcomes.len());
    for (n, w) in narrow.outcomes.iter().zip(&wide.outcomes) {
        assert_eq!(n.id, w.id);
        assert_eq!(n.label, w.label);
        assert_eq!(n.status, w.status);
        assert_eq!(n.provenance, w.provenance);
        assert_eq!(n.launch_cycles, w.launch_cycles);
    }
    assert_eq!(narrow.device_util.len(), 1);
    assert_eq!(wide.device_util.len(), 4);
    let busy = |r: &volt::serve::ServeReport| -> u64 {
        r.device_util.iter().map(|d| d.busy_cycles).sum()
    };
    assert_eq!(busy(&narrow), busy(&wide), "total work is schedule-invariant");
    assert!(
        wide.makespan_cycles <= narrow.makespan_cycles,
        "more devices cannot lengthen the makespan"
    );
}

/// Identical in-flight requests dedup through the shared session tier:
/// misses == distinct fingerprints, everything else is served from mem.
#[test]
fn dedup_in_flight_misses_equal_distinct_fingerprints() {
    let mut reqs = vec![];
    for _ in 0..3 {
        reqs.push(ServeRequest::registry("vecadd", OptLevel::Recon));
    }
    for _ in 0..2 {
        reqs.push(ServeRequest::registry("saxpy", OptLevel::Recon));
    }
    reqs.push(ServeRequest::registry("vecadd", OptLevel::O3));
    let rep = Service::new(ServeConfig::default()).run(reqs);
    assert_eq!(rep.cache.misses, 3, "three distinct (source, options) keys");
    assert_eq!(rep.cache.hits, 3, "every repeat must be a mem hit");
    assert_eq!(rep.outcomes[0].provenance, Some(Provenance::Miss));
    assert_eq!(rep.outcomes[1].provenance, Some(Provenance::Mem));
    assert_eq!(rep.outcomes[2].provenance, Some(Provenance::Mem));
    assert!(rep.outcomes.iter().all(|o| o.status == RequestStatus::Pass));
}

/// A chaos request that exhausts its retry budget latches only its own
/// stream; clean neighbors in the same batch (and the shared compile
/// tier) are untouched.
#[test]
fn faulted_request_is_isolated_from_neighbors() {
    let mut chaos = ServeRequest::registry("vecadd", OptLevel::Recon);
    chaos.faults = FaultPlan::none()
        .with(0, FaultKind::IllegalTrap { pc: None })
        .with(0, FaultKind::IllegalTrap { pc: None });
    chaos.class = "faulty";
    let reqs = vec![
        chaos,
        ServeRequest::registry("vecadd", OptLevel::Recon),
        ServeRequest::registry("saxpy", OptLevel::Recon),
    ];
    let rep = Service::new(ServeConfig::default()).run(reqs);
    assert_eq!(rep.outcomes[0].status, RequestStatus::Faulted);
    assert!(rep.outcomes[0].injected > 0);
    assert_eq!(rep.outcomes[1].status, RequestStatus::Pass);
    assert_eq!(rep.outcomes[2].status, RequestStatus::Pass);
    // The faulted request compiled vecadd into the shared tier; its
    // clean twin still rides that compile.
    assert_eq!(rep.outcomes[1].provenance, Some(Provenance::Mem));
    assert_eq!(rep.clean_failures(), 0);
}

/// Admission: priority classes first, FIFO within a class; overflow is
/// turned away as Rejected outcomes, not errors.
#[test]
fn queue_cap_rejects_overflow_by_priority_then_fifo() {
    let mut reqs = vec![];
    for prio in [
        Priority::Low,    // id 0 — rejected
        Priority::Normal, // id 1 — admitted (first normal)
        Priority::High,   // id 2 — admitted
        Priority::Normal, // id 3 — rejected (second normal)
        Priority::High,   // id 4 — admitted
    ] {
        let mut r = ServeRequest::registry("vecadd", OptLevel::Recon);
        r.priority = prio;
        reqs.push(r);
    }
    let rep = Service::new(ServeConfig {
        queue_cap: 3,
        ..ServeConfig::default()
    })
    .run(reqs);
    assert_eq!(rep.count(RequestStatus::Rejected), 2);
    for o in &rep.outcomes {
        let rejected = o.status == RequestStatus::Rejected;
        assert_eq!(rejected, o.id == 0 || o.id == 3, "outcome {}: {:?}", o.id, o.status);
        if rejected {
            assert!(o.error.as_deref().unwrap().contains("queue capacity"));
        }
    }
    // Rejected outcomes serialize device as -1 and stay valid JSON.
    let json = rep.render_json();
    volt::prof::validate_json(&json).unwrap();
    assert!(json.contains("\"device\":-1"));
}

/// The manifest front door, end to end: repeats, per-request retry
/// overrides and chaos plans.
#[test]
fn manifest_batch_runs_end_to_end() {
    let text = "# smoke\nvecadd repeat=2 prio=high\nsaxpy inject=trap@0 retries=2\n";
    let reqs = parse_manifest(text, std::path::Path::new("."), OptLevel::Recon).unwrap();
    let rep = Service::new(ServeConfig::default()).run(reqs);
    assert_eq!(rep.outcomes.len(), 3);
    assert_eq!(rep.outcomes[0].status, RequestStatus::Pass);
    assert_eq!(rep.outcomes[1].status, RequestStatus::Pass);
    assert_eq!(rep.outcomes[2].status, RequestStatus::Recovered);
    assert_eq!(rep.outcomes[2].retries, 1);
    assert_eq!(rep.clean_failures(), 0);
}

/// A second service pointed at the same cache directory replays the
/// whole workload from the persistent tier: zero recompiles, same
/// statuses.
#[test]
fn second_service_at_same_cache_dir_serves_from_disk() {
    let dir = tmpdir("svc");
    let cfg = ServeConfig {
        devices: 2,
        retries: 1,
        seed: 3,
        cache_dir: Some(dir.clone()),
        ..ServeConfig::default()
    };
    let cold = serve_synthetic(20, cfg.clone());
    assert_eq!(cold.cache.disk_hits, 0, "first run finds an empty directory");
    assert!(cold.cache.misses > 0);

    let warm = serve_synthetic(20, cfg);
    assert_eq!(warm.cache.misses, 0, "warm run must not recompile anything");
    assert!(warm.cache.disk_hits > 0);
    assert_eq!(warm.quarantined, 0);
    for (c, w) in cold.outcomes.iter().zip(&warm.outcomes) {
        assert_eq!(c.status, w.status, "cache tier must not change results");
    }
    // Disk-served compiles are cheaper in the latency model.
    let (cold_p50, _, _) = cold.latency_percentiles();
    let (warm_p50, _, _) = warm.latency_percentiles();
    assert!(warm_p50 < cold_p50, "warm p50 {warm_p50} vs cold {cold_p50}");
    let _ = std::fs::remove_dir_all(&dir);
}

const K1: &str = "kernel void k1(global int* x) { int i = get_global_id(0); x[i] = i + 1; }";
const K2: &str = "kernel void k2(global int* x) { int i = get_global_id(0); x[i] = i + 2; }";
const K3: &str = "kernel void k3(global int* x) { int i = get_global_id(0); x[i] = i + 3; }";

/// Two sessions interleaved over one disk-cache directory: each serves
/// the other's compiles, counters stay exact, nothing is quarantined,
/// and a size cap evicts the least-recently-used entry — not the one a
/// sibling session just touched.
#[test]
fn sessions_share_a_disk_dir_with_exact_stats_and_lru_eviction() {
    let dir = tmpdir("shared");
    let opts = VoltOptions::default;
    let a = Session::with_disk_cache(opts(), &dir, 0);
    let b = Session::with_disk_cache(opts(), &dir, 0);

    // Interleave: A compiles, B rides A's stores, then B hits its own
    // mem tier.
    let p1 = a.compile(K1).unwrap();
    assert_eq!(b.compile(K1).unwrap().fingerprint, p1.fingerprint);
    let p2 = a.compile(K2).unwrap();
    assert_eq!(b.compile(K2).unwrap().fingerprint, p2.fingerprint);
    b.compile(K1).unwrap();

    let sa = a.cache_stats();
    assert_eq!((sa.misses, sa.hits, sa.disk_hits), (2, 0, 0));
    let sb = b.cache_stats();
    assert_eq!((sb.misses, sb.hits, sb.disk_hits), (0, 1, 2));
    assert_eq!(a.disk_quarantined(), Some(0));
    assert_eq!(b.disk_quarantined(), Some(0));

    // K1/K2/K3 are the same shape, so their entries are the same size:
    // a cap of two entries (plus one byte) forces exactly one eviction.
    let s1 = std::fs::metadata(a.disk_entry_path(p1.fingerprint).unwrap())
        .unwrap()
        .len();
    let s2 = std::fs::metadata(a.disk_entry_path(p2.fingerprint).unwrap())
        .unwrap()
        .len();
    assert_eq!(s1, s2, "equal-shape kernels must store equal-size entries");

    let c = Session::with_disk_cache(opts(), &dir, s1 + s2 + 1);
    c.compile(K1).unwrap(); // disk hit — touches K1, leaving K2 as LRU
    c.compile(K3).unwrap(); // miss + store — over cap, evicts K2
    let sc = c.cache_stats();
    assert_eq!((sc.misses, sc.disk_hits, sc.disk_evicted), (1, 1, 1));
    let key3 = fingerprint(K3, &opts());
    assert!(
        !c.disk_entry_path(p2.fingerprint).unwrap().exists(),
        "LRU entry must go"
    );
    assert!(
        c.disk_entry_path(p1.fingerprint).unwrap().exists(),
        "touched entry must stay"
    );
    assert!(c.disk_entry_path(key3).unwrap().exists());
    let _ = std::fs::remove_dir_all(&dir);
}
