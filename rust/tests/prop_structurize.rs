//! Property test: random goto-spaghetti kernels (irreducible CFGs) —
//! structurization / reconstruction must yield reducible CFGs with
//! preserved semantics at every ladder level.

use volt::coordinator::propcheck::{check, PropConfig};
use volt::coordinator::Rng;
use volt::frontend::{compile, FrontendOptions};
use volt::ir::cfg::is_reducible;
use volt::ir::interp::{read_u32, run_kernel_scalar};
use volt::transform::{run_middle_end, OptLevel};

/// Random goto graph: L labeled sections, each mutating state and jumping
/// to a random label (forward or back) under a data-dependent condition,
/// with a step counter bounding execution.
fn gen_goto_kernel(rng: &mut Rng, size: u32) -> String {
    let nl = 3 + (rng.next_u32() % (size.max(2) / 2 + 1)).min(5) as usize;
    let mut body = String::new();
    body.push_str("    int i = get_global_id(0);\n    int x = i;\n    int steps = 0;\n");
    for l in 0..nl {
        body.push_str(&format!("sec{l}:\n"));
        body.push_str("    steps = steps + 1;\n");
        body.push_str(&format!(
            "    if (steps > 40) goto finish;\n    x = x * {} + {};\n",
            (rng.next_u32() % 5) + 1,
            rng.next_u32() % 9
        ));
        // 1-2 conditional jumps to arbitrary labels.
        for _ in 0..1 + (rng.next_u32() % 2) {
            let target = (rng.next_u32() as usize) % nl;
            let c = rng.next_u32() % 7;
            body.push_str(&format!(
                "    if ((x & 15) == {c}) goto sec{target};\n"
            ));
        }
    }
    body.push_str("finish:\n    out[i] = x + steps * 1000;\n");
    format!("kernel void k(global int* out) {{\n{body}}}\n")
}

fn interp_out(m: &volt::ir::Module, n: u32) -> Result<Vec<u32>, String> {
    let k = m.find_func("k").ok_or("no kernel")?;
    let mut mem = vec![0u8; 1 << 20];
    let out0 = 0x1000u32;
    run_kernel_scalar(
        m,
        k,
        &[out0],
        [1, 1, 1],
        [n, 1, 1],
        &mut mem,
        1 << 18,
        &[],
    )
    .map_err(|e| format!("interp: {e}"))?;
    Ok((0..n).map(|i| read_u32(&mem, out0 + i * 4)).collect())
}

#[test]
fn goto_kernels_structurize_soundly() {
    let cfg = PropConfig {
        cases: 12,
        seed: 0x60706070,
    };
    check(&cfg, |rng, size| {
        let src = gen_goto_kernel(rng, size);
        let m0 = compile(&src, &FrontendOptions::default()).map_err(|e| e.to_string())?;
        let want = interp_out(&m0, 16).map_err(|e| format!("{e}\n{src}"))?;
        for lvl in [OptLevel::Base, OptLevel::ZiCond, OptLevel::Recon] {
            let mut m = m0.clone();
            let mut c = lvl.config();
            c.verify = true;
            run_middle_end(&mut m, &c);
            let kf = m.find_func("k").unwrap();
            if !is_reducible(&m.funcs[kf.idx()]) {
                return Err(format!("not reducible at {lvl:?}\n{src}"));
            }
            let got = interp_out(&m, 16).map_err(|e| format!("{e} at {lvl:?}\n{src}"))?;
            if got != want {
                return Err(format!("semantics broken at {lvl:?}\n{src}"));
            }
        }
        Ok(())
    });
}

/// Reconstruction actually fires on divergent irreducible regions and
/// reduces dispatcher count relative to pure structurization.
#[test]
fn reconstruction_reduces_dispatchers() {
    let src = r#"
kernel void k(global int* out) {
    int i = get_global_id(0);
    int x = i;
    if (x % 2 == 0) goto b;
a:
    x = x + 1;
    if (x % 5 != 0) goto b;
    goto done;
b:
    x = x + 10;
    if (x < 100) goto a;
done:
    out[i] = x;
}
"#;
    let m0 = compile(src, &FrontendOptions::default()).unwrap();
    let want = interp_out(&m0, 16).unwrap();
    // Without Recon: dispatcher path.
    let mut m_plain = m0.clone();
    let mut c1 = OptLevel::ZiCond.config();
    c1.verify = true;
    let rep_plain = run_middle_end(&mut m_plain, &c1);
    // With Recon: duplication path.
    let mut m_recon = m0.clone();
    let mut c2 = OptLevel::Recon.config();
    c2.verify = true;
    let rep_recon = run_middle_end(&mut m_recon, &c2);
    assert!(rep_plain.structurize_dispatchers > 0, "{rep_plain:?}");
    assert!(
        rep_recon.recon_duplicated > 0,
        "reconstruction should duplicate: {rep_recon:?}"
    );
    assert!(rep_recon.structurize_dispatchers <= rep_plain.structurize_dispatchers);
    assert_eq!(interp_out(&m_plain, 16).unwrap(), want);
    assert_eq!(interp_out(&m_recon, 16).unwrap(), want);
}
