//! Acceptance tests for the `volt::prof` subsystem:
//!
//! * the per-core stall breakdown sums exactly to the run's cycle count;
//! * >= 90% of executed PCs map to a source line on at least 5 benchmark
//!   kernels (crt0 startup excluded — it is runtime, not source);
//! * the chrome-trace JSON round-trips through a real JSON parser;
//! * profiling is a pure observer: cycles and device results are
//!   bit-identical with it on and off (determinism guard);
//! * stream event cycle stamps are monotonically non-decreasing across
//!   h2d → launch → d2h and copies take zero device cycles.

use volt::coordinator::{benchmarks, experiments};
use volt::driver::{CommandKind, Session, VoltOptions};
use volt::prof::validate_json;
use volt::runtime::ArgValue;
use volt::transform::OptLevel;

const DIVERGE_SRC: &str = r#"
kernel void mix(global int* data, global int* hist, int n) {
    local int tile[64];
    int l = get_local_id(0);
    int g = get_global_id(0);
    tile[l] = data[g];
    barrier(0);
    int acc = 0;
    for (int k = 0; k < l % 5; k++) { acc += tile[(l + k) % 64]; }
    if (g < n) { atomic_add(hist + (acc % 8), 1); data[g] = acc; }
}
"#;

fn profiled_session() -> Session {
    Session::new(
        VoltOptions::builder()
            .profiling(true)
            .build()
            .unwrap(),
    )
}

#[test]
fn stall_breakdown_sums_to_total_cycles() {
    let mut s = profiled_session();
    let p = s.compile(DIVERGE_SRC).unwrap();
    let mut st = s.create_stream(&p);
    let data = st.malloc(128 * 4);
    let hist = st.malloc(8 * 4);
    st.enqueue_write_u32(data, &(0..128u32).collect::<Vec<_>>()).unwrap();
    st.enqueue_write_u32(hist, &[0u32; 8]).unwrap();
    st.enqueue_launch(
        "mix",
        [2, 1, 1],
        [64, 1, 1],
        &[ArgValue::Ptr(data), ArgValue::Ptr(hist), ArgValue::I32(128)],
    )
    .unwrap();
    st.synchronize().unwrap();
    assert_eq!(st.profiles().len(), 1);
    let prof = &st.profiles()[0];
    assert_eq!(prof.kernel, "mix");
    assert!(prof.cycles > 0);
    // Per core: every simulated cycle is attributed exactly once.
    for (ci, core) in prof.per_core.iter().enumerate() {
        assert_eq!(
            core.total(),
            prof.cycles,
            "core {ci}: issue {} + stalls {:?} != cycles {}",
            core.issue_cycles,
            core.stalls,
            prof.cycles
        );
    }
    // Aggregate view: total == cycles x cores.
    assert_eq!(
        prof.stalls.total(),
        prof.cycles * prof.num_cores as u64
    );
    // This kernel has barriers, memory traffic and a divergent loop —
    // the taxonomy should see issues plus at least memory stalls.
    assert!(prof.stalls.issue > 0);
    assert!(prof.stalls.memory > 0, "{:?}", prof.stalls);
    assert!(prof.occupancy_pct > 0.0 && prof.occupancy_pct <= 100.0);
    // The render never panics and carries the key sections.
    let txt = volt::prof::render_text(prof, 5);
    assert!(txt.contains("core-cycle breakdown"));
}

#[test]
fn source_line_coverage_across_benchmarks() {
    // ISSUE acceptance: >=90% of executed PCs map to a source line for
    // at least 5 benchmark kernels.
    let names = ["vecadd", "saxpy", "sgemm", "reduce", "pathfinder", "transpose"];
    let mut passing = 0;
    for name in names {
        let b = benchmarks::find(name).unwrap();
        let (_, profiles) =
            experiments::profile_bench(&b, OptLevel::Recon).unwrap_or_else(|e| panic!("{e}"));
        assert!(!profiles.is_empty(), "{name}: no launches profiled");
        let ok = profiles.iter().all(|p| p.mapped_pct() >= 90.0);
        assert!(
            ok,
            "{name}: mapped {:?}",
            profiles.iter().map(|p| p.mapped_pct()).collect::<Vec<_>>()
        );
        // Hot lines must point into the kernel source (1-based lines).
        for p in &profiles {
            assert!(!p.hot_lines.is_empty(), "{name}: no hot lines");
            assert!(p.hot_lines.iter().all(|(l, _)| *l >= 1));
        }
        passing += 1;
    }
    assert!(passing >= 5);
}

#[test]
fn chrome_trace_round_trips_through_json_parser() {
    let mut s = profiled_session();
    let p = s.compile(DIVERGE_SRC).unwrap();
    let mut st = s.create_stream(&p);
    let data = st.malloc(128 * 4);
    let hist = st.malloc(8 * 4);
    st.enqueue_write_u32(data, &(0..128u32).collect::<Vec<_>>()).unwrap();
    st.enqueue_write_u32(hist, &[0u32; 8]).unwrap();
    st.enqueue_launch(
        "mix",
        [2, 1, 1],
        [64, 1, 1],
        &[ArgValue::Ptr(data), ArgValue::Ptr(hist), ArgValue::I32(128)],
    )
    .unwrap();
    let t = st.enqueue_read_u32(data, 128);
    st.synchronize().unwrap();
    let _ = st.take_u32(t).unwrap();
    let trace = st.chrome_trace();
    validate_json(&trace).unwrap_or_else(|e| panic!("trace invalid: {e}\n{trace}"));
    assert!(trace.contains("\"traceEvents\""));
    // Stream slices (one per command) and per-core tracks are present.
    assert!(trace.contains("\"cat\":\"launch\""));
    assert!(trace.contains("\"cat\":\"h2d\""));
    assert!(trace.contains("core0"));
    assert!(trace.contains("warps.core0"));
}

#[test]
fn profiling_is_deterministic_and_invisible() {
    // Determinism guard: identical cycles and identical device results
    // with profiling off and on.
    let src = DIVERGE_SRC;
    let run = |profiling: bool| -> (u64, Vec<u32>, Vec<u32>) {
        let s = Session::new(
            VoltOptions::builder().profiling(profiling).build().unwrap(),
        );
        let p = s.compile(src).unwrap();
        let mut st = s.create_stream(&p);
        let data = st.malloc(128 * 4);
        let hist = st.malloc(8 * 4);
        st.enqueue_write_u32(data, &(0..128u32).collect::<Vec<_>>()).unwrap();
        st.enqueue_write_u32(hist, &[0u32; 8]).unwrap();
        st.enqueue_launch(
            "mix",
            [2, 1, 1],
            [64, 1, 1],
            &[ArgValue::Ptr(data), ArgValue::Ptr(hist), ArgValue::I32(128)],
        )
        .unwrap();
        let td = st.enqueue_read_u32(data, 128);
        let th = st.enqueue_read_u32(hist, 8);
        st.synchronize().unwrap();
        let cycles = st.stats().cycles;
        (cycles, st.take_u32(td).unwrap(), st.take_u32(th).unwrap())
    };
    let (c_off, d_off, h_off) = run(false);
    let (c_on, d_on, h_on) = run(true);
    assert_eq!(c_off, c_on, "profiling changed SimStats.cycles");
    assert_eq!(d_off, d_on, "profiling changed device results (data)");
    assert_eq!(h_off, h_on, "profiling changed device results (hist)");
    assert!(c_off > 0);
}

#[test]
fn stream_event_stamps_are_monotonic_and_copies_free() {
    let mut s = profiled_session();
    let p = s
        .compile(
            r#"
kernel void scale(global int* x, int n) {
    int i = get_global_id(0);
    if (i < n) x[i] = x[i] * 3;
}
"#,
        )
        .unwrap();
    let mut st = s.create_stream(&p);
    let buf = st.malloc(64 * 4);
    st.enqueue_write_u32(buf, &(0..64u32).collect::<Vec<_>>()).unwrap();
    st.enqueue_launch(
        "scale",
        [1, 1, 1],
        [64, 1, 1],
        &[ArgValue::Ptr(buf), ArgValue::I32(64)],
    )
    .unwrap();
    let t = st.enqueue_read_u32(buf, 64);
    st.synchronize().unwrap();
    assert_eq!(st.take_u32(t).unwrap()[5], 15);
    let ev = st.events();
    assert_eq!(ev.len(), 3);
    assert_eq!(ev[0].kind, CommandKind::H2D);
    assert_eq!(ev[1].kind, CommandKind::Launch);
    assert_eq!(ev[2].kind, CommandKind::D2H);
    // Monotonically non-decreasing stamps across h2d -> launch -> d2h.
    let mut prev = 0u64;
    for e in ev {
        assert!(e.start_cycles >= prev, "start went backwards: {e:?}");
        assert!(e.end_cycles >= e.start_cycles, "negative duration: {e:?}");
        prev = e.end_cycles;
    }
    // Copies are host-side: zero device cycles.
    assert_eq!(ev[0].start_cycles, ev[0].end_cycles, "h2d took device cycles");
    assert_eq!(ev[2].start_cycles, ev[2].end_cycles, "d2h took device cycles");
    // The launch is the only command consuming device time.
    assert!(ev[1].end_cycles > ev[1].start_cycles);
}

#[test]
fn spill_traffic_is_visible_per_line() {
    // A narrow register file forces spills; the profiler must attribute
    // their latency-weighted cycles (KernelProfile::spill_cycles) and
    // mark the lines in the annotated listing. Also checks the
    // fast-forward invariant through the driver: cycles and per-core
    // stall sums are identical with the idle-cycle skip on and off.
    let src = r#"
kernel void pressure(global int* out, int n) {
    int i = get_global_id(0);
    int a = i * 3 + 1;
    int b = i * 5 + 2;
    int c = i * 7 + 3;
    int d = i * 11 + 4;
    int e = a * b + c * d;
    int f = (a + b) * (c + d);
    int g = e ^ f;
    int h = (a & c) + (b | d);
    if (i < n) { out[i] = e + f + g + h + a + b + c + d; }
}
"#;
    let narrow = volt::target::TargetDesc {
        regfile: volt::target::RegFile {
            int_alloc: (5, 9),
            ..volt::target::RegFile::vortex()
        },
        ..volt::target::TargetDesc::vortex()
    };
    let run = |fast_forward: bool| {
        let s = Session::new(
            VoltOptions::builder()
                .profiling(true)
                .opt_level(OptLevel::O3)
                .target_desc(narrow)
                .sim(volt::sim::SimConfig {
                    fast_forward,
                    ..volt::sim::SimConfig::from_target(&narrow)
                })
                .build()
                .unwrap(),
        );
        let p = s.compile(src).unwrap();
        let mut st = s.create_stream(&p);
        let out = st.malloc(128 * 4);
        st.enqueue_write_u32(out, &[0u32; 128]).unwrap();
        st.enqueue_launch(
            "pressure",
            [2, 1, 1],
            [64, 1, 1],
            &[ArgValue::Ptr(out), ArgValue::I32(128)],
        )
        .unwrap();
        let t = st.enqueue_read_u32(out, 128);
        st.synchronize().unwrap();
        let data = st.take_u32(t).unwrap();
        (st.profiles()[0].clone(), data)
    };
    let (prof, data) = run(true);
    let (prof_noff, data_noff) = run(false);
    assert!(prof.spill_cycles > 0, "narrow regfile must show spill cycles");
    assert!(!prof.spill_lines.is_empty(), "spill lines must be attributed");
    for (line, cyc) in &prof.spill_lines {
        assert!(*line >= 1 && *cyc > 0);
    }
    let listing = volt::prof::annotate_source(src, &prof);
    assert!(listing.contains("s!"), "annotate must mark spill traffic:\n{listing}");
    // Fast-forward invariance through the driver path.
    assert_eq!(prof.cycles, prof_noff.cycles, "fast-forward changed cycles");
    assert_eq!(data, data_noff);
    for core in &prof.per_core {
        assert_eq!(core.total(), prof.cycles, "ledger must sum under fast-forward");
    }
    assert_eq!(prof.stalls.total(), prof_noff.stalls.total());
    assert_eq!(prof.spill_cycles, prof_noff.spill_cycles);
}

#[test]
fn hot_line_lands_in_kernel_body() {
    // The docs' worked example: the hot line of sgemm_tiled must be a
    // real body line of the kernel source, not the signature.
    let b = benchmarks::find("sgemm_tiled").unwrap();
    let (_, profiles) = experiments::profile_bench(&b, OptLevel::Recon).unwrap();
    let p = profiles.iter().max_by_key(|p| p.cycles).unwrap();
    let (line, cycles) = p.hot_lines[0];
    let n_lines = b.source.lines().count() as u32;
    assert!(line >= 1 && line <= n_lines, "hot line {line} outside source");
    assert!(cycles > 0);
    // An annotated listing renders one row per source line.
    let listing = volt::prof::annotate_source(b.source, p);
    assert_eq!(listing.lines().count() as u32, n_lines + 1); // + header
}
