//! End-to-end integration: the full benchmark suite compiles, runs and
//! validates; the ladder behaves per §5.2; the safety net (Fig. 5) is
//! both necessary and sufficient; the Fig. 9/10 axes produce the paper's
//! qualitative orderings.

use volt::backend::emit::{BackendOptions, SharedMemMapping};
use volt::coordinator::{benchmarks, experiments};
use volt::frontend::FrontendOptions;
use volt::sim::SimConfig;
use volt::transform::OptLevel;

/// §5.1 coverage at the ladder extremes for the whole registry.
#[test]
fn full_suite_validates_at_base_and_recon() {
    for b in benchmarks::registry() {
        for lvl in [OptLevel::Base, OptLevel::Recon] {
            experiments::run_bench(
                &b,
                lvl,
                true,
                SharedMemMapping::Local,
                SimConfig::default(),
            )
            .unwrap_or_else(|e| panic!("{}: {e}", b.name));
        }
    }
}

/// Fig. 7 directionality: the full ladder never *increases* dynamic
/// instructions on the divergence-sensitive kernels, and strictly helps on
/// the uniform-loop ones.
#[test]
fn ladder_reduces_instructions() {
    for name in ["saxpy", "sgemm", "kmeans", "backprop", "pathfinder"] {
        let b = benchmarks::find(name).unwrap();
        let base = experiments::run_bench(
            &b,
            OptLevel::Base,
            true,
            SharedMemMapping::Local,
            SimConfig::default(),
        )
        .unwrap();
        let full = experiments::run_bench(
            &b,
            OptLevel::Recon,
            true,
            SharedMemMapping::Local,
            SimConfig::default(),
        )
        .unwrap();
        assert!(
            full.stats.instrs < base.stats.instrs,
            "{name}: {} !< {}",
            full.stats.instrs,
            base.stats.instrs
        );
        assert!(
            full.stats.cycles <= base.stats.cycles,
            "{name}: cycles regressed"
        );
    }
}

/// The kmeans ladder staircase (annotated loads → Uni-Ann, helper args →
/// Uni-Func) — the §5.2 "annotation pass is important" observation.
#[test]
fn kmeans_ladder_staircase() {
    let b = benchmarks::find("kmeans").unwrap();
    let mut instrs = vec![];
    for lvl in [
        OptLevel::UniHw,
        OptLevel::UniAnn,
        OptLevel::UniFunc,
    ] {
        let r = experiments::run_bench(
            &b,
            lvl,
            true,
            SharedMemMapping::Local,
            SimConfig::default(),
        )
        .unwrap();
        instrs.push(r.stats.instrs);
    }
    assert!(
        instrs[1] < instrs[0],
        "Uni-Ann must beat Uni-HW on kmeans: {instrs:?}"
    );
    assert!(
        instrs[2] < instrs[1],
        "Uni-Func must beat Uni-Ann on kmeans: {instrs:?}"
    );
}

/// ZiCond trades instructions for memory requests (§5.2's density
/// observation on pathfinder/transpose-style ternary kernels).
#[test]
fn zicond_density_tradeoff() {
    let b = benchmarks::find("pathfinder").unwrap();
    let pre = experiments::run_bench(
        &b,
        OptLevel::UniFunc,
        true,
        SharedMemMapping::Local,
        SimConfig::default(),
    )
    .unwrap();
    let zi = experiments::run_bench(
        &b,
        OptLevel::ZiCond,
        true,
        SharedMemMapping::Local,
        SimConfig::default(),
    )
    .unwrap();
    assert!(zi.stats.instrs < pre.stats.instrs, "fewer instructions");
    assert!(
        zi.stats.mem_requests > pre.stats.mem_requests,
        "higher memory-request density: {} !> {}",
        zi.stats.mem_requests,
        pre.stats.mem_requests
    );
}

/// Fig. 9: hardware warp primitives beat software emulation on every
/// warp-feature benchmark.
#[test]
fn fig9_hw_beats_sw_everywhere() {
    let rows = experiments::isa_extension_sweep().unwrap();
    assert!(rows.len() >= 5);
    for r in &rows {
        assert!(
            r.speedup() > 1.0,
            "{}: sw {} vs hw {}",
            r.name,
            r.sw_cycles,
            r.hw_cycles
        );
        assert!(r.hw_instrs < r.sw_instrs, "{}", r.name);
    }
    // vote benefits most (paper ordering: vote >> shuffle).
    let vote = rows.iter().find(|r| r.name == "vote").unwrap();
    let shfl = rows.iter().find(|r| r.name == "shuffle").unwrap();
    assert!(vote.speedup() > shfl.speedup());
}

/// Fig. 10: scratchpad shared memory is at least as fast as the
/// global-memory mapping, results identical.
#[test]
fn fig10_smem_mapping() {
    for name in ["sgemm_tiled", "stencil"] {
        let b = benchmarks::find(name).unwrap();
        let local = experiments::run_bench(
            &b,
            OptLevel::Recon,
            true,
            SharedMemMapping::Local,
            SimConfig::default(),
        )
        .unwrap();
        let global = experiments::run_bench(
            &b,
            OptLevel::Recon,
            true,
            SharedMemMapping::Global,
            SimConfig::default(),
        )
        .unwrap();
        assert!(
            local.stats.cycles < global.stats.cycles,
            "{name}: local {} !< global {}",
            local.stats.cycles,
            global.stats.cycles
        );
    }
}

/// Fig. 5(a) necessity: with the block-layout pass on and the safety net
/// OFF, swapped split arms mis-execute (wrong lanes take the then-side);
/// with the net ON the program is correct. The hazard is real and the
/// repair works.
#[test]
fn safety_net_is_necessary_and_sufficient() {
    let src = r#"
kernel void k(global int* out) {
    int i = get_global_id(0);
    int v;
    if (i % 2 == 0) { v = 100; } else { v = 200; }
    out[i] = v;
}
"#;
    let fe = FrontendOptions::default();
    let run_with = |safety: bool| -> Result<Vec<u32>, String> {
        let out = volt::coordinator::compile_source(
            src,
            &fe,
            OptLevel::Recon,
            &BackendOptions {
                safety_net: safety,
                ..Default::default()
            },
        )?;
        let mut dev =
            volt::runtime::VoltDevice::new(out.image.clone(), SimConfig::default());
        let buf = dev.malloc(32 * 4);
        dev.launch(
            "k",
            [1, 1, 1],
            [32, 1, 1],
            &[volt::runtime::ArgValue::Ptr(buf)],
        )
        .map_err(|e| e.to_string())?;
        dev.read_u32s(buf, 32).map_err(|e| e.to_string())
    };
    let good = run_with(true).expect("safety net on must work");
    for (i, v) in good.iter().enumerate() {
        assert_eq!(*v, if i % 2 == 0 { 100 } else { 200 });
    }
    // Net off: either the sim traps or the values are wrong — the hazard
    // must be observable whenever the layout actually swapped arms.
    match run_with(false) {
        Err(_) => {} // trap: acceptable manifestation
        Ok(vals) => {
            let wrong = vals
                .iter()
                .enumerate()
                .any(|(i, v)| *v != if i % 2 == 0 { 100 } else { 200 });
            // If layout didn't swap for this program, values match; accept
            // but verify the hazard machinery via the MIR unit tests.
            if !wrong {
                eprintln!("note: layout produced no swap for this kernel");
            }
        }
    }
}

/// Compile-time: the full ladder must not blow up compile time (§5.2's
/// 0.18% claim — here we allow generous slack; the ladder often *saves*
/// time because simpler IR reaches the back-end).
#[test]
fn compile_time_overhead_bounded() {
    let rows = experiments::compile_time_sweep(2).unwrap();
    let g = experiments::geomean(rows.iter().map(|r| r.full_ms / r.base_ms));
    assert!(
        g < 1.5,
        "full-ladder compile time blew up: geomean ratio {g}"
    );
}
