//! Driver-API integration: sessions, the binary cache, multi-kernel
//! programs, stream ordering and typed-error behavior (ISSUE 1).

use std::sync::Arc;
use volt::backend::emit::SharedMemMapping;
use volt::driver::{CommandKind, Session, VoltError, VoltOptions};
use volt::frontend::Dialect;
use volt::runtime::{ArgValue, RuntimeError};
use volt::sim::SimConfig;
use volt::transform::OptLevel;

const TWO_KERNELS: &str = r#"
kernel void init(global float* x, int n) {
    int i = get_global_id(0);
    if (i < n) x[i] = (float)i;
}
kernel void scale(global float* x, float a, int n) {
    int i = get_global_id(0);
    if (i < n) x[i] = x[i] * a;
}
"#;

/// Regression for the seed's `kernels[0]`-only image: both kernels of a
/// two-kernel source must be launchable, from one compile, through the
/// stream API alone.
#[test]
fn two_kernels_from_one_source_both_launch() {
    let session = Session::new(VoltOptions::builder().build().unwrap());
    let program = session.compile(TWO_KERNELS).unwrap();
    assert_eq!(program.kernel_names(), vec!["init", "scale"]);

    let n = 96u32;
    let mut stream = session.create_stream(&program);
    let buf = stream.malloc(n * 4);
    stream
        .enqueue_launch(
            "init",
            [1, 1, 1],
            [96, 1, 1],
            &[ArgValue::Ptr(buf), ArgValue::I32(n as i32)],
        )
        .unwrap();
    stream
        .enqueue_launch(
            "scale",
            [1, 1, 1],
            [96, 1, 1],
            &[ArgValue::Ptr(buf), ArgValue::F32(2.5), ArgValue::I32(n as i32)],
        )
        .unwrap();
    let out = stream.enqueue_read_f32(buf, n as usize);
    stream.synchronize().unwrap();
    let got = stream.take_f32(out).unwrap();
    for (i, v) in got.iter().enumerate() {
        assert_eq!(*v, i as f32 * 2.5, "element {i}");
    }
    // Both launches recorded, in order, with advancing cycle timestamps.
    let launches: Vec<_> = stream
        .events()
        .iter()
        .filter(|e| e.kind == CommandKind::Launch)
        .collect();
    assert_eq!(launches.len(), 2);
    assert_eq!(launches[0].label, "init");
    assert_eq!(launches[1].label, "scale");
    assert!(launches[0].end_cycles <= launches[1].start_cycles);
}

#[test]
fn cache_hits_by_content_and_options() {
    let session = Session::new(VoltOptions::builder().build().unwrap());
    let p1 = session.compile(TWO_KERNELS).unwrap();
    let p2 = session.compile(TWO_KERNELS).unwrap();
    assert!(Arc::ptr_eq(&p1, &p2), "identical source must hit");
    assert_eq!(session.cache_stats().hits, 1);
    assert_eq!(session.cache_stats().misses, 1);

    // Whitespace change = different content = miss.
    let src2 = TWO_KERNELS.replace("x[i] * a", "x[i]  * a");
    session.compile(&src2).unwrap();
    assert_eq!(session.cache_stats().misses, 2);

    // Same source under different output-relevant options: different key.
    let base = Session::new(
        VoltOptions::builder()
            .opt_level(OptLevel::Base)
            .build()
            .unwrap(),
    );
    let p3 = base.compile(TWO_KERNELS).unwrap();
    assert_ne!(p1.fingerprint, p3.fingerprint);
}

#[test]
fn options_validation_rejects_bad_combos() {
    for (built, what) in [
        (
            VoltOptions::builder()
                .opt_level(OptLevel::UniFunc)
                .force_zicond(true)
                .build(),
            "zicond below ZiCond",
        ),
        (
            VoltOptions::builder()
                .opt_level(OptLevel::ZiCond)
                .safety_net(false)
                .build(),
            "safety net off below Recon",
        ),
        (
            VoltOptions::builder()
                .smem(SharedMemMapping::Global)
                .sim(SimConfig {
                    num_cores: 64,
                    ..SimConfig::default()
                })
                .build(),
            "global smem with too many cores",
        ),
        (
            VoltOptions::builder()
                .warp_hw(false)
                .sim(SimConfig {
                    warps_per_core: 32,
                    ..SimConfig::default()
                })
                .build(),
            "software warp emulation beyond scratch",
        ),
    ] {
        let e = built.expect_err(what);
        assert!(matches!(e, VoltError::InvalidOptions { .. }), "{what}: {e}");
        assert_eq!(e.stage(), "options", "{what}");
    }
    // The legitimate Fig. 5 configuration still builds.
    assert!(VoltOptions::builder()
        .opt_level(OptLevel::Recon)
        .safety_net(false)
        .build()
        .is_ok());
}

#[test]
fn error_variants_round_trip_their_stage() {
    let session = Session::new(VoltOptions::builder().build().unwrap());

    // Frontend: bad syntax carries the line.
    let e = session
        .compile("kernel void k(global int* o) {\n  o[0] = ;\n}")
        .unwrap_err();
    assert_eq!(e.stage(), "frontend");
    assert_eq!(e.line(), Some(2));
    assert!(e.to_string().contains("line 2"), "{e}");

    // Frontend: semantic failure (unknown function) also typed.
    let e = session
        .compile("kernel void k(global int* o) { o[0] = nosuch(3); }")
        .unwrap_err();
    assert!(matches!(e, VoltError::Frontend { .. }), "{e}");

    // Stream misuse: unknown kernel is typed before anything runs.
    let program = session.compile(TWO_KERNELS).unwrap();
    let mut stream = session.create_stream(&program);
    let e = stream
        .enqueue_launch("nope", [1, 1, 1], [1, 1, 1], &[])
        .unwrap_err();
    assert_eq!(e.stage(), "stream");

    // Runtime: an over-sized block surfaces as Runtime(BadLaunch) at
    // synchronize time.
    let buf = stream.malloc(16);
    stream
        .enqueue_launch(
            "init",
            [1, 1, 1],
            [4096, 1, 1],
            &[ArgValue::Ptr(buf), ArgValue::I32(4)],
        )
        .unwrap();
    let e = stream.synchronize().unwrap_err();
    assert_eq!(e.stage(), "runtime");
    assert!(
        matches!(e, VoltError::Runtime(RuntimeError::BadLaunch(_))),
        "{e}"
    );
    // The queue behind the failing command is intact and usable again.
    stream
        .enqueue_launch(
            "init",
            [1, 1, 1],
            [4, 1, 1],
            &[ArgValue::Ptr(buf), ArgValue::I32(4)],
        )
        .unwrap();
    stream.synchronize().unwrap();
}

#[test]
fn transfer_handles_are_bound_to_their_stream() {
    let session = Session::new(VoltOptions::builder().build().unwrap());
    let program = session.compile(TWO_KERNELS).unwrap();
    let mut a = session.create_stream(&program);
    let mut b = session.create_stream(&program);
    let buf_a = a.malloc(16);
    let t = a.enqueue_read_u32(buf_a, 4);
    a.synchronize().unwrap();
    // Redeeming A's handle on B is a typed error, not someone else's data.
    let e = b.take_u32(t).unwrap_err();
    assert!(matches!(e, VoltError::Stream { .. }), "{e}");
    assert!(e.to_string().contains("different stream"), "{e}");
}

#[test]
fn odd_length_transfers_are_typed_errors_for_typed_takes() {
    let session = Session::new(VoltOptions::builder().build().unwrap());
    let program = session.compile(TWO_KERNELS).unwrap();
    let mut st = session.create_stream(&program);
    let buf = st.malloc(64);
    let t = st.enqueue_read(buf, 6); // not a multiple of 4
    st.synchronize().unwrap();
    let e = st.take_u32(t).unwrap_err();
    assert!(e.to_string().contains("multiple of 4"), "{e}");
    // The raw-bytes path still serves arbitrary lengths.
    let t2 = st.enqueue_read(buf, 6);
    st.synchronize().unwrap();
    assert_eq!(st.take_bytes(t2).unwrap().len(), 6);
}

#[test]
fn symbol_writes_are_bounds_checked_at_enqueue() {
    let session = Session::new(VoltOptions::builder().build().unwrap());
    let program = session
        .compile(
            r#"
__constant__ float lut[4] = { 1.0f, 2.0f, 3.0f, 4.0f };
kernel void k(global float* o) {
    o[get_global_id(0)] = lut[0];
}
"#,
        )
        .unwrap();
    let mut st = session.create_stream(&program);
    // In-range write is accepted.
    st.enqueue_write_symbol("lut", &[0u8; 16], 0).unwrap();
    // Past the end: typed stream error before anything runs.
    let e = st.enqueue_write_symbol("lut", &[0u8; 16], 4).unwrap_err();
    assert!(matches!(e, VoltError::Stream { .. }), "{e}");
    assert!(e.to_string().contains("out of range"), "{e}");
    let e = st.enqueue_write_symbol("nosuch", &[0u8; 4], 0).unwrap_err();
    assert!(e.to_string().contains("unknown device symbol"), "{e}");
}

/// The CUDA dialect flows through the same session/stream path.
#[test]
fn cuda_dialect_session_roundtrip() {
    let session = Session::new(
        VoltOptions::builder()
            .dialect(Dialect::Cuda)
            .build()
            .unwrap(),
    );
    let program = session
        .compile(
            r#"
__global__ void add2(float* x, int n) {
    int i = blockIdx.x * blockDim.x + threadIdx.x;
    if (i < n) x[i] = x[i] + 2.0f;
}
"#,
        )
        .unwrap();
    let mut stream = session.create_stream(&program);
    let buf = stream.malloc(64 * 4);
    stream.enqueue_write_f32(buf, &[1.0f32; 64]).unwrap();
    stream
        .enqueue_launch(
            "add2",
            [1, 1, 1],
            [64, 1, 1],
            &[ArgValue::Ptr(buf), ArgValue::I32(64)],
        )
        .unwrap();
    let t = stream.enqueue_read_f32(buf, 64);
    stream.synchronize().unwrap();
    assert_eq!(stream.take_f32(t).unwrap(), vec![3.0f32; 64]);
}

/// A cache hit must be dramatically cheaper than a cold compile; the
/// wall-clock claim lives in `benches/recompile_cache.rs`, here we verify
/// the mechanism (same Arc, no recompilation side effects).
#[test]
fn cache_hit_reuses_the_exact_program() {
    let session = Session::new(VoltOptions::builder().build().unwrap());
    let cold = std::time::Instant::now();
    let p1 = session.compile(TWO_KERNELS).unwrap();
    let cold_ms = cold.elapsed().as_secs_f64() * 1e3;
    let warm = std::time::Instant::now();
    let p2 = session.compile(TWO_KERNELS).unwrap();
    let warm_ms = warm.elapsed().as_secs_f64() * 1e3;
    assert!(Arc::ptr_eq(&p1, &p2));
    // Generous bound to stay robust under CI noise; the bench demonstrates
    // the real (>=10x) margin.
    assert!(
        warm_ms <= cold_ms,
        "cache hit ({warm_ms:.3} ms) slower than cold compile ({cold_ms:.3} ms)"
    );
}
