//! Property tests over the uniformity analysis:
//!
//! * **monotonicity** — enabling more analysis (the §5.2 ladder) never
//!   increases the number of divergent values or divergent branches;
//! * **soundness via the simulator** — any value the analysis calls
//!   uniform that actually diverges would leave a uniform `CondBr` in the
//!   binary, and the simulator traps on non-uniform branch conditions.
//!   (The full-pipeline property in prop_compile.rs exercises this; here
//!   we assert the analysis-level invariants directly.)

use volt::analysis::tti::VortexTti;
use volt::analysis::{uniformity, UniformityOptions};
use volt::coordinator::propcheck::{check, PropConfig};
use volt::coordinator::Rng;
use volt::frontend::{compile, FrontendOptions};
use volt::transform::{mem2reg, simplify};

fn gen_kernel(rng: &mut Rng, size: u32) -> String {
    let mut body = String::new();
    body.push_str("    int i = get_global_id(0);\n    int v = a[i];\n    int acc = 0;\n");
    for s in 0..(2 + rng.next_u32() % size.max(1)) {
        match rng.next_u32() % 4 {
            0 => body.push_str(&format!(
                "    if (v % {} == 0) acc += {}; else acc -= v;\n",
                rng.next_u32() % 9 + 2,
                rng.next_u32() % 100
            )),
            1 => body.push_str(&format!(
                "    for (int k{s} = 0; k{s} < n; k{s}++) acc += k{s};\n"
            )),
            2 => body.push_str(&format!(
                "    for (int d{s} = 0; d{s} < (v & 3); d{s}++) acc ^= d{s};\n"
            )),
            _ => body.push_str("    acc = acc > 0 ? acc - i : acc + 1;\n"),
        }
    }
    format!(
        "kernel void k(global int* out, global int* a, uniform int n) {{\n{body}    out[i] = acc;\n}}\n"
    )
}

#[test]
fn ladder_is_monotone() {
    let ladder = [
        UniformityOptions::default(),
        UniformityOptions {
            uni_hw: true,
            ..Default::default()
        },
        UniformityOptions {
            uni_hw: true,
            uni_ann: true,
            uni_func: false,
        },
        UniformityOptions::all(),
    ];
    check(
        &PropConfig {
            cases: 20,
            seed: 0xAB1E,
        },
        |rng, size| {
            let src = gen_kernel(rng, size);
            let mut m = compile(&src, &FrontendOptions::default()).map_err(|e| e.to_string())?;
            let k = m.find_func("k").unwrap();
            // SSA form for a meaningful analysis.
            mem2reg::run(&mut m.funcs[k.idx()]);
            simplify::simplify(&mut m.funcs[k.idx()]);
            let mut prev_div = usize::MAX;
            let mut prev_branches = usize::MAX;
            for opts in &ladder {
                let u = uniformity::analyze(&m, k, opts, &VortexTti);
                let nd = u.num_divergent();
                let nb = u.div_branch_blocks.len();
                if nd > prev_div || nb > prev_branches {
                    return Err(format!(
                        "ladder not monotone: {nd}/{nb} after {prev_div}/{prev_branches} at {opts:?}\n{src}"
                    ));
                }
                prev_div = nd;
                prev_branches = nb;
            }
            Ok(())
        },
    );
}

#[test]
fn lane_id_rooted_values_stay_divergent() {
    // No amount of analysis may mark gid-derived data uniform.
    check(
        &PropConfig {
            cases: 12,
            seed: 0xD177,
        },
        |rng, size| {
            let src = gen_kernel(rng, size);
            let m = {
                let mut m =
                    compile(&src, &FrontendOptions::default()).map_err(|e| e.to_string())?;
                let k = m.find_func("k").unwrap();
                mem2reg::run(&mut m.funcs[k.idx()]);
                m
            };
            let k = m.find_func("k").unwrap();
            let u = uniformity::analyze(&m, k, &UniformityOptions::all(), &VortexTti);
            let f = m.func(k);
            // The out[i] store's address must be divergent (i is per-lane).
            for inst in f.insts.iter().filter(|i| !i.dead) {
                if let volt::ir::InstKind::Store { ptr, .. } = &inst.kind {
                    if let volt::ir::Val::Inst(p) = ptr {
                        if let volt::ir::InstKind::Gep { index, .. } = &f.inst(*p).kind {
                            if u.val_div(*index) {
                                return Ok(()); // found the divergent store index
                            }
                        }
                    }
                }
            }
            Err(format!("no divergent store index found\n{src}"))
        },
    );
}
