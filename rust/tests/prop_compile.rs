//! Property test: random structured kernels, compiled at random ladder
//! levels through the FULL pipeline (frontend → middle-end → backend →
//! simulator), must produce the same memory image as the scalar IR
//! interpreter oracle running the pre-middle-end kernel.
//!
//! This single property transitively checks structurization, divergence
//! insertion, register allocation, encoding and the simulator's IPDOM
//! semantics: any unsound "uniform" claim trips the simulator's
//! non-uniform-branch trap; any broken reconvergence corrupts results.

use volt::backend::{build_image, BackendOptions};
use volt::coordinator::propcheck::{check, PropConfig};
use volt::coordinator::Rng;
use volt::frontend::{compile, compile_kernels, FrontendOptions};
use volt::ir::interp::{read_u32, run_kernel_scalar, write_u32};
use volt::sim::{Gpu, SimConfig};
use volt::transform::{run_middle_end, OptLevel};

/// Generate a random kernel over `out`, `a` (ints) and scalar n.
fn gen_kernel(rng: &mut Rng, size: u32) -> String {
    let mut body = String::new();
    let mut vars = vec!["i".to_string(), "v".to_string()];
    body.push_str("    int i = get_global_id(0);\n");
    body.push_str("    int v = a[i];\n");
    let nstmt = 2 + (rng.next_u32() % size.max(1)) as usize;
    for s in 0..nstmt {
        let pick = rng.next_u32() % 10;
        // never mutate the index var `i`: out[i] stores must stay
        // lane-private or the program is racy and order-dependent.
        let mut_vars: Vec<&String> = vars.iter().filter(|v| *v != "i").collect();
        let var = mut_vars[(rng.next_u32() as usize) % mut_vars.len()].clone();
        let rhs_var = vars[(rng.next_u32() as usize) % vars.len()].clone();
        let c1 = (rng.next_u32() % 13) as i32 + 1;
        let c2 = (rng.next_u32() % 7) as i32;
        match pick {
            0..=2 => {
                let op = ["+", "-", "*", "^", "&", "|"][(rng.next_u32() as usize) % 6];
                body.push_str(&format!("    {var} = ({var} {op} {rhs_var}) + {c2};\n"));
            }
            3..=4 => {
                let cmp = ["<", ">", "==", "!="][(rng.next_u32() as usize) % 4];
                body.push_str(&format!(
                    "    if ({var} % {c1} {cmp} {c2}) {{ {var} = {var} * 3 + 1; }} else {{ {var} = {var} - {rhs_var}; }}\n"
                ));
            }
            5 => {
                body.push_str(&format!(
                    "    {var} = {var} > {c2} ? {var} - {rhs_var} : {var} + {c1};\n"
                ));
            }
            6..=7 => {
                let nv = format!("t{s}");
                body.push_str(&format!(
                    "    int {nv} = 0;\n    for (int k{s} = 0; k{s} < ({var} & 7); k{s}++) {{ {nv} = {nv} + k{s} + ({rhs_var} & 3); }}\n"
                ));
                vars.push(nv);
            }
            8 => {
                let nv = format!("u{s}");
                body.push_str(&format!(
                    "    int {nv} = 0;\n    for (int q{s} = 0; q{s} < n; q{s}++) {{ {nv} = {nv} + q{s}; }}\n"
                ));
                vars.push(nv);
            }
            _ => {
                body.push_str(&format!(
                    "    if ({var} == {c1}) {{ out[i] = 9999; return; }}\n"
                ));
            }
        }
    }
    let fold = vars
        .iter()
        .map(|v| v.as_str())
        .collect::<Vec<_>>()
        .join(" ^ ");
    format!(
        "kernel void k(global int* out, global int* a, int n) {{\n{body}    out[i] = {fold};\n}}\n"
    )
}

#[test]
fn random_kernels_match_scalar_oracle() {
    let cfg = PropConfig {
        cases: 24,
        seed: 0xC0FFEE,
    };
    check(&cfg, |rng, size| {
        let src = gen_kernel(rng, size);
        let lvl = OptLevel::LADDER[(rng.next_u32() as usize) % OptLevel::LADDER.len()];
        run_case(&src, lvl).map_err(|e| format!("{e}\nsource:\n{src}"))
    });
}

/// A fixed stress case: deep nesting + early returns + loops together.
#[test]
fn nested_stress_kernel_all_levels() {
    let src = r#"
kernel void k(global int* out, global int* a, int n) {
    int i = get_global_id(0);
    int v = a[i];
    if (i % 3 == 0) {
        for (int k = 0; k < (v & 7); k++) {
            if (k % 2 == 0) { v += k; } else { v -= 1; }
            if (v == 13) { out[i] = 777; return; }
        }
    } else {
        if (v > 500) { out[i] = 1; return; }
        v = v > 250 ? v - 250 : v + 3;
    }
    int u = 0;
    for (int q = 0; q < n; q++) { u += q * (i & 1); }
    out[i] = v + u;
}
"#;
    for lvl in OptLevel::LADDER {
        run_case(src, lvl).unwrap_or_else(|e| panic!("{e}"));
    }
}

fn run_case(src: &str, lvl: OptLevel) -> Result<(), String> {
    const N: u32 = 64;
    let n_arg = 5u32;
    // Oracle: pre-middle-end kernel through the scalar interpreter.
    let m0 = compile(src, &FrontendOptions::default()).map_err(|e| e.to_string())?;
    let k = m0.find_func("k").ok_or("no kernel")?;
    let mut mem = vec![0u8; 1 << 20];
    let out0 = 0x1000u32;
    let a0 = 0x2000u32;
    for i in 0..N {
        write_u32(&mut mem, a0 + i * 4, i.wrapping_mul(2654435761) % 1000);
    }
    run_kernel_scalar(
        &m0,
        k,
        &[out0, a0, n_arg],
        [2, 1, 1],
        [32, 1, 1],
        &mut mem,
        1 << 18,
        &[],
    )
    .map_err(|e| format!("oracle: {e}"))?;
    let want: Vec<u32> = (0..N).map(|i| read_u32(&mem, out0 + i * 4)).collect();

    // Full pipeline + simulator.
    let (mut m, infos) =
        compile_kernels(src, &FrontendOptions::default()).map_err(|e| e.to_string())?;
    let mut mcfg = lvl.config();
    mcfg.verify = true;
    run_middle_end(&mut m, &mcfg);
    let image = build_image(
        &m,
        &format!("__main_{}", infos[0].name),
        &BackendOptions {
            zicond: lvl >= OptLevel::ZiCond,
            ..Default::default()
        },
    )?;
    let sim_cfg = SimConfig {
        num_cores: 2,
        warps_per_core: 4,
        heap_bytes: 1 << 20,
        ..SimConfig::default()
    };
    let mut gpu = Gpu::load(&image, sim_cfg);
    let out = gpu.alloc(N * 4);
    let a = gpu.alloc(N * 4);
    for i in 0..N {
        gpu.mem
            .write_u32(a + i * 4, i.wrapping_mul(2654435761) % 1000)
            .map_err(|e| format!("seed: {e:?}"))?;
    }
    let args_addr = gpu.image_args_addr;
    let entry = image.func_entries[&format!("__main_{}", infos[0].name)];
    for (off, v) in [
        (0u32, 2u32),
        (4, 1),
        (8, 1),
        (12, 32),
        (16, 1),
        (20, 1),
        (24, entry),
        (28, out),
        (32, a),
        (36, n_arg),
    ] {
        gpu.mem
            .write_u32(args_addr + off, v)
            .map_err(|e| format!("args: {e:?}"))?;
    }
    let _stats = gpu.run().map_err(|e| format!("sim @ {lvl:?}: {e}"))?;
    for i in 0..N {
        let got = gpu
            .mem
            .read_u32(out + i * 4)
            .map_err(|e| format!("{e:?}"))?;
        if got != want[i as usize] {
            return Err(format!(
                "lane {i} mismatch at {lvl:?}: got {got}, want {}",
                want[i as usize]
            ));
        }
    }
    Ok(())
}
