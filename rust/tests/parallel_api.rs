//! Cross-layer contract of the host parallelism work (ISSUE 9): worker
//! threads change wall clock, never results.
//!
//! * the parallel cycle-barrier simulator is bit-identical to the
//!   sequential engine on every target, including under the runtime
//!   sanitizer and the profiler's per-core ledgers;
//! * concurrent compiles of the same fingerprint through a shared
//!   [`Session`] dedup to exactly one pipeline run;
//! * the serve batch drained by a worker pool reports byte-identically
//!   to the sequential virtual-time ledger.

use std::sync::{Barrier, Mutex};

use volt::backend::emit::SharedMemMapping;
use volt::coordinator::benchmarks;
use volt::coordinator::experiments::{run_bench, run_bench_on_threads};
use volt::driver::{compile_program, CompileTier, Session, VoltOptions};
use volt::runtime::VoltDevice;
use volt::serve::{synthetic, ServeConfig, Service};
use volt::sim::SimConfig;
use volt::target::TargetDesc;
use volt::transform::OptLevel;

/// A ladder slice wide enough to cover the engine's interesting corners:
/// plain streams, shared-memory tiles, barriers, divergence-heavy graph
/// traversal, and multi-launch iteration.
const KERNELS: [&str; 8] = [
    "vecadd",
    "sgemm",
    "sgemm_tiled",
    "transpose",
    "reduce",
    "stencil",
    "bfs",
    "kmeans",
];

/// The full `SimStats` rendering — every counter, the print log and the
/// sanitizer report list. Two runs agree here iff they are bit-identical.
fn sig(stats: &volt::sim::SimStats) -> String {
    format!("{stats:?}")
}

#[test]
fn parallel_sim_is_bit_identical_on_every_target() {
    for target_name in ["vortex", "vortex-min"] {
        let target = TargetDesc::by_name(target_name).unwrap();
        for name in KERNELS {
            let b = benchmarks::find(name).unwrap();
            let base = run_bench_on_threads(&b, &target, OptLevel::O3, 1).unwrap();
            for threads in [2usize, 4] {
                let par = run_bench_on_threads(&b, &target, OptLevel::O3, threads).unwrap();
                assert_eq!(
                    sig(&par.stats),
                    sig(&base.stats),
                    "{name} on {target_name}: {threads}-thread sim diverged from sequential"
                );
            }
        }
    }
}

#[test]
fn sanitizer_verdicts_identical_under_parallel_sim() {
    // Shared-memory kernels exercise the sanitizer's barrier and
    // smem-range checks; its report list rides SimStats, so the
    // signature comparison covers verdict text and ordering.
    for name in ["reduce", "sgemm_tiled", "stencil"] {
        let b = benchmarks::find(name).unwrap();
        let run = |threads: usize| {
            let cfg = SimConfig {
                sanitize: true,
                threads,
                ..SimConfig::default()
            };
            run_bench(&b, OptLevel::O3, true, SharedMemMapping::Local, cfg).unwrap()
        };
        let base = run(1);
        let par = run(4);
        assert_eq!(
            sig(&par.stats),
            sig(&base.stats),
            "{name}: sanitized 4-thread run diverged from sequential"
        );
    }
}

#[test]
fn profiler_ledger_identical_under_parallel_sim() {
    // The profiler's per-core cycle ledgers (stall attribution, PC
    // samples, hot lines) are the finest-grained observable state the
    // simulator exposes; they must not notice the worker pool either.
    for name in ["sgemm", "reduce"] {
        let b = benchmarks::find(name).unwrap();
        let run = |threads: usize| {
            let mut opts = VoltOptions::builder()
                .dialect(b.dialect)
                .target_desc(TargetDesc::vortex())
                .opt_level(OptLevel::O3)
                .build()
                .unwrap();
            opts.sim.threads = threads;
            let prog = compile_program(b.source, &opts).unwrap();
            let mut dev = VoltDevice::new(prog.image.clone(), opts.device_config());
            dev.profiling = true;
            (b.run)(&mut dev).unwrap();
            (sig(&dev.total_stats), format!("{:?}", dev.take_profiles()))
        };
        let (base_stats, base_prof) = run(1);
        let (par_stats, par_prof) = run(4);
        assert_eq!(par_stats, base_stats, "{name}: stats diverged under profiler");
        assert_eq!(par_prof, base_prof, "{name}: profile ledgers diverged");
    }
}

#[test]
fn concurrent_compiles_dedup_to_one_pipeline_run() {
    let b = benchmarks::find("vecadd").unwrap();
    let session = Session::new(VoltOptions {
        dialect: b.dialect,
        ..VoltOptions::default()
    });
    let barrier = Barrier::new(4);
    let results = Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for _ in 0..4 {
            scope.spawn(|| {
                barrier.wait();
                let r = session.compile_traced(b.source).unwrap();
                results.lock().unwrap().push(r);
            });
        }
    });
    let results = results.into_inner().unwrap();
    assert_eq!(results.len(), 4);
    let misses = results
        .iter()
        .filter(|(_, t)| *t == CompileTier::Miss)
        .count();
    assert_eq!(misses, 1, "exactly one racer may run the pipeline");
    assert!(
        results
            .iter()
            .all(|(p, _)| std::sync::Arc::ptr_eq(p, &results[0].0)),
        "all racers must share one Program"
    );
    let st = session.cache_stats();
    assert_eq!((st.misses, st.hits), (1, 3));
    assert_eq!(session.cached_programs(), 1);
}

fn serve_json(count: usize, cfg: ServeConfig) -> String {
    let reqs = synthetic(count, cfg.seed);
    Service::new(cfg).run(reqs).render_json()
}

#[test]
fn threaded_serve_report_is_schedule_equivalent() {
    for devices in [2usize, 4] {
        let cfg = |threads: usize| ServeConfig {
            devices,
            retries: 1,
            seed: 11,
            threads,
            ..ServeConfig::default()
        };
        let sequential = serve_json(64, cfg(1));
        volt::prof::validate_json(&sequential).unwrap();
        for threads in [2usize, 4, 0] {
            assert_eq!(
                serve_json(64, cfg(threads)),
                sequential,
                "serve report must be byte-identical at {threads} threads ({devices} devices)"
            );
        }
    }
}
