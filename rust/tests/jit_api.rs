//! Trace-caching warp JIT contract (docs/SIMJIT.md): the JIT changes
//! wall clock, never results.
//!
//! Every test here is a differential between `SimConfig::jit` off (the
//! pure interpreter) and on (trace dispatch + cycle-exact replay),
//! through the public API alone:
//!
//! * full-`SimStats` bit-identity on every registry kernel, on both
//!   shipped targets;
//! * the profiler's per-core cycle ledgers and per-PC samples agree;
//! * the runtime sanitizer reaches the same verdicts on the entire
//!   buggy corpus;
//! * an armed fault plan fires at exactly the same cycles with
//!   identical corruption / identical trap errors;
//! * the JIT composes with the parallel cycle-barrier engine.

use volt::check::buggy;
use volt::coordinator::benchmarks;
use volt::coordinator::experiments::run_bench_on_configured;
use volt::driver::{compile_program, VoltOptions};
use volt::runtime::{ArgValue, VoltDevice};
use volt::sim::{FaultKind, FaultPlan, SimConfig, SimStats};
use volt::target::TargetDesc;
use volt::transform::OptLevel;

/// The full `SimStats` rendering — every counter, the print log and the
/// sanitizer report list. Two runs agree here iff they are bit-identical.
fn sig(stats: &SimStats) -> String {
    format!("{stats:?}")
}

#[test]
fn jit_is_bit_identical_on_every_kernel_and_target() {
    for target_name in ["vortex", "vortex-min"] {
        let target = TargetDesc::by_name(target_name).unwrap();
        for b in benchmarks::registry() {
            let off = run_bench_on_configured(&b, &target, OptLevel::O3, 1, false)
                .unwrap_or_else(|e| panic!("{} on {target_name} (jit off): {e}", b.name));
            let on = run_bench_on_configured(&b, &target, OptLevel::O3, 1, true)
                .unwrap_or_else(|e| panic!("{} on {target_name} (jit on): {e}", b.name));
            assert_eq!(
                sig(&on.stats),
                sig(&off.stats),
                "{} on {target_name}: jit run diverged from interpreter",
                b.name
            );
        }
    }
}

#[test]
fn profiler_ledger_identical_with_jit() {
    // The profiler's per-core cycle ledgers (stall attribution, per-PC
    // issue counts and latency samples) are the finest-grained
    // observable the simulator exposes; the replay queue re-issues every
    // trace instruction at its exact interpreter cycle, so the ledgers
    // must not notice the JIT.
    for name in ["sgemm", "sgemm_tiled", "reduce", "bfs"] {
        let b = benchmarks::find(name).unwrap();
        let run = |jit: bool| {
            let mut opts = VoltOptions::builder()
                .dialect(b.dialect)
                .target_desc(TargetDesc::vortex())
                .opt_level(OptLevel::O3)
                .build()
                .unwrap();
            opts.sim.jit = jit;
            let prog = compile_program(b.source, &opts).unwrap();
            let mut dev = VoltDevice::new(prog.image.clone(), opts.device_config());
            dev.profiling = true;
            (b.run)(&mut dev).unwrap();
            (sig(&dev.total_stats), format!("{:?}", dev.take_profiles()))
        };
        let (off_stats, off_prof) = run(false);
        let (on_stats, on_prof) = run(true);
        assert_eq!(on_stats, off_stats, "{name}: stats diverged under profiler");
        assert_eq!(on_prof, off_prof, "{name}: profile ledgers diverged");
    }
}

#[test]
fn sanitizer_verdicts_identical_on_buggy_corpus() {
    // The whole 10-kernel corpus, including the barrier-divergence cases
    // that deadlock deterministically: the rendered launch outcome (full
    // stats + sanitizer reports on success, the exact error on failure)
    // must be byte-identical with the JIT on or off.
    for case in buggy::all() {
        let launch = |jit: bool| {
            let opts = VoltOptions::builder().dialect(case.dialect).build().unwrap();
            let prog = compile_program(case.source, &opts)
                .unwrap_or_else(|e| panic!("{}: {e}", case.name));
            let cfg = SimConfig {
                sanitize: true,
                jit,
                ..opts.device_config()
            };
            let mut dev = VoltDevice::new(prog.image.clone(), cfg);
            let n = 64usize;
            let input: Vec<f32> = (0..n).map(|i| i as f32 + 1.0).collect();
            let a = dev.malloc(n as u32 * 4);
            let b = dev.malloc(n as u32 * 4);
            dev.write_f32(a, &input).unwrap();
            dev.write_f32(b, &vec![0.0; n]).unwrap();
            let kernel = prog.kernels[0].name.clone();
            let r = dev.launch(
                &kernel,
                [1, 1, 1],
                [
                    case.block[0] as u32,
                    case.block[1] as u32,
                    case.block[2] as u32,
                ],
                &[ArgValue::Ptr(a), ArgValue::Ptr(b)],
            );
            format!("{r:?}")
        };
        assert_eq!(
            launch(true),
            launch(false),
            "{}: sanitized outcome diverged with jit on",
            case.name
        );
        if case.sanitizer_catchable() {
            let out = launch(true);
            assert!(
                out.starts_with("Ok(") && !out.contains("sanitize_reports: []"),
                "{}: corpus case should complete with a non-empty report list",
                case.name
            );
        }
    }
}

const INC: &str = r#"
kernel void inc(global int* x, int n) {
    int i = get_global_id(0);
    if (i < n) x[i] = x[i] * 3 + 1;
}
"#;

fn inc_device(faults: FaultPlan, jit: bool) -> VoltDevice {
    let opts = VoltOptions::builder().build().unwrap();
    let prog = compile_program(INC, &opts).unwrap();
    let cfg = SimConfig {
        faults,
        jit,
        ..opts.device_config()
    };
    VoltDevice::new(prog.image.clone(), cfg)
}

fn run_inc(dev: &mut VoltDevice) -> Result<(SimStats, Vec<u32>), volt::runtime::RuntimeError> {
    let buf = dev.malloc(64 * 4);
    dev.write_u32s(buf, &[7u32; 64])?;
    let stats = dev.launch("inc", [1, 1, 1], [64, 1, 1], &[ArgValue::Ptr(buf), ArgValue::I32(64)])?;
    let out = dev.read_u32s(buf, 64)?;
    Ok((stats, out))
}

#[test]
fn armed_fault_plan_fires_identically_with_jit() {
    // An armed plan disables trace dispatch entirely (guard 2 in
    // docs/SIMJIT.md), so injection must hit the same instruction at the
    // same cycle either way. LoadBitFlip is the sharpest probe: it
    // corrupts the destination of *the next executed load*, so any
    // reordering or cycle drift changes the corrupted value.
    let flip = FaultPlan::none().with(5, FaultKind::LoadBitFlip { bit: 3 });
    let (s_off, r_off) = run_inc(&mut inc_device(flip, false)).unwrap();
    let (s_on, r_on) = run_inc(&mut inc_device(flip, true)).unwrap();
    assert_eq!(r_on, r_off, "bit-flip corruption must land identically");
    assert_eq!(sig(&s_on), sig(&s_off));

    let mut off = inc_device(flip, false);
    let mut on = inc_device(flip, true);
    run_inc(&mut off).unwrap();
    run_inc(&mut on).unwrap();
    assert_eq!(off.gpu.faults.injected(), 1);
    assert_eq!(on.gpu.faults.injected(), 1);
    assert_eq!(on.gpu.faults.log, off.gpu.faults.log, "injection cycles must match");

    // Trap faults: the rendered error (core, warp, pc, [injected] tag)
    // is byte-identical too.
    let trap = FaultPlan::none().with(9, FaultKind::IllegalTrap { pc: None });
    let e_off = run_inc(&mut inc_device(trap, false)).unwrap_err();
    let e_on = run_inc(&mut inc_device(trap, true)).unwrap_err();
    assert_eq!(format!("{e_on:?}"), format!("{e_off:?}"));

    // And a plan armed far past the run: never fires, but its mere
    // presence parks the JIT — still identical to the interpreter AND
    // to an unarmed jit-on run.
    let never = FaultPlan::none().with(u64::MAX / 2, FaultKind::MemTrap { pc: None });
    let (s_armed, r_armed) = run_inc(&mut inc_device(never, true)).unwrap();
    let (s_plain, r_plain) = run_inc(&mut inc_device(FaultPlan::none(), true)).unwrap();
    assert_eq!(r_armed, r_plain);
    assert_eq!(sig(&s_armed), sig(&s_plain));
}

#[test]
fn jit_composes_with_parallel_sim() {
    // jit × threads: the trace cache and replay queue are core-private,
    // so the cycle-barrier worker pool must not observe them either.
    let target = TargetDesc::vortex();
    for name in ["sgemm", "bfs"] {
        let b = benchmarks::find(name).unwrap();
        let base = run_bench_on_configured(&b, &target, OptLevel::O3, 1, false).unwrap();
        for threads in [2usize, 4] {
            let jitted = run_bench_on_configured(&b, &target, OptLevel::O3, threads, true).unwrap();
            assert_eq!(
                sig(&jitted.stats),
                sig(&base.stats),
                "{name}: jit @ {threads} threads diverged from sequential interpreter"
            );
        }
    }
}
