//! Backend codegen-quality rung acceptance: interp-differential coverage
//! for the MIR combine pass + upgraded register allocator over the whole
//! benchmark registry, on both built-in targets, plus the narrow-regfile
//! CAS/CMOV spill-pressure differential.
//!
//! "Differential" here means: the same middle-end output is lowered with
//! the rung on and off, both images run their benchmark's host-side
//! validator (which asserts exact expected device results), and the
//! on/off device outputs are therefore bit-identical whenever both
//! validators pass.

use volt::backend::emit::{build_image, BackendOptions, ProgramImage};
use volt::coordinator::benchmarks::{self, Benchmark};
use volt::frontend::{compile_kernels, FrontendOptions};
use volt::runtime::VoltDevice;
use volt::sim::{SimConfig, SimStats};
use volt::target::TargetDesc;
use volt::transform::{run_middle_end_with, OptLevel};

/// Lower one benchmark at O3 for `target` with the backend rung on or
/// off, sharing the middle-end output between the two lowerings.
fn build_pair(b: &Benchmark, target: &TargetDesc) -> (ProgramImage, ProgramImage) {
    let fe = FrontendOptions {
        dialect: b.dialect,
        warp_hw: target.default_warp_hw(),
    };
    let (mut m, infos) =
        compile_kernels(b.source, &fe).unwrap_or_else(|e| panic!("{}: {e:?}", b.name));
    let mut cfg = OptLevel::O3.config();
    cfg.features = target.features;
    run_middle_end_with(&mut m, &cfg, target);
    let dispatcher = format!("__main_{}", infos[0].name);
    let mk = |codegen_opt: bool| -> ProgramImage {
        build_image(
            &m,
            &dispatcher,
            &BackendOptions {
                zicond: target.features.zicond,
                codegen_opt,
                target: *target,
                ..Default::default()
            },
        )
        .unwrap_or_else(|e| panic!("{} (codegen_opt={codegen_opt}): {e}", b.name))
    };
    (mk(true), mk(false))
}

/// Run a benchmark's validator against a prebuilt image; returns the
/// accumulated stats (the validator itself asserts device results).
fn validate(b: &Benchmark, img: &ProgramImage, target: &TargetDesc) -> SimStats {
    let mut dev = VoltDevice::new(img.clone(), SimConfig::from_target(target));
    (b.run)(&mut dev).unwrap_or_else(|e| panic!("{} on {}: {e}", b.name, target.name));
    dev.total_stats.clone()
}

/// The satellite acceptance: every registry kernel at O3 on vortex,
/// validators pass with the rung on AND off (so results are bit-exact
/// both ways), and across the suite the rung strictly reduces dynamic
/// instructions and cycles.
#[test]
fn combine_differential_all_kernels_vortex() {
    let target = TargetDesc::vortex();
    let (mut cyc_on, mut cyc_off) = (0u64, 0u64);
    let (mut ins_on, mut ins_off) = (0u64, 0u64);
    for b in benchmarks::registry() {
        let (on, off) = build_pair(&b, &target);
        let s_on = validate(&b, &on, &target);
        let s_off = validate(&b, &off, &target);
        // Per kernel: the rung must not cost more than noise (cache
        // interleaving can shift a little when instructions disappear);
        // the hard zero-regression gate is benches/o3_cycles.rs's
        // Recon-vs-O3 comparison.
        assert!(
            s_on.cycles <= s_off.cycles + s_off.cycles / 100,
            "{}: backend rung regressed cycles ({} > {})",
            b.name,
            s_on.cycles,
            s_off.cycles
        );
        cyc_on += s_on.cycles;
        cyc_off += s_off.cycles;
        ins_on += s_on.instrs;
        ins_off += s_off.instrs;
    }
    assert!(
        ins_on < ins_off,
        "rung must cut dynamic instructions suite-wide ({ins_on} !< {ins_off})"
    );
    assert!(
        cyc_on < cyc_off,
        "rung must cut cycles suite-wide ({cyc_on} !< {cyc_off})"
    );
}

/// The same differential on vortex-min (no ZiCond/shfl/vote: selects
/// legalized to branches, warp builtins through the software emulation)
/// over a representative non-warp subset — validators pass and no
/// kernel regresses.
#[test]
fn combine_differential_vortex_min_subset() {
    let target = TargetDesc::vortex_min();
    for name in ["saxpy", "reduce", "pathfinder", "sgemm", "bfs", "psum"] {
        let b = benchmarks::find(name).unwrap();
        let (on, off) = build_pair(&b, &target);
        // Gated-op audit still holds on the optimized image.
        for inst in &on.code {
            assert!(
                target.supports_op(inst.op),
                "{name}: gated op {:?} in a vortex-min image",
                inst.op
            );
        }
        let s_on = validate(&b, &on, &target);
        let s_off = validate(&b, &off, &target);
        assert!(
            s_on.cycles <= s_off.cycles + s_off.cycles / 100,
            "{name}: rung regressed on vortex-min ({} > {})",
            s_on.cycles,
            s_off.cycles
        );
    }
}

/// Spill-scratch collision under real execution: a kernel whose CMOV
/// (ternary) and AMOCAS (atomic_cmpxchg) operands all spill on a
/// narrow register file. The device results with the rung on must be
/// bit-identical to the rung-off lowering AND to the full register
/// file — if T5/T6/T7 ever aliased, the read-modify-write destination
/// would clobber a reloaded source and the buffers would differ.
#[test]
fn narrow_regfile_cas_cmov_pressure_differential() {
    let src = r#"
kernel void stress(global int* out, global int* lock, int n) {
    int i = get_global_id(0);
    int a = i * 3 + 1;
    int b = i * 5 + 2;
    int c = i * 7 + 3;
    int d = i * 11 + 4;
    int e = a * b + c * d;
    int f = a + b + c + d;
    int g = e ^ f;
    int h = (a & c) + (b | d);
    int v = 0;
    if (i % 2 == 0) { v = e + h; } else { v = f + g; }
    atomic_cmpxchg(lock + (i % 4), 0, i + 1);
    if (i < n) { out[i] = v + a + e - g; }
}
"#;
    let narrow = TargetDesc {
        regfile: volt::target::RegFile {
            int_alloc: (5, 10),
            ..volt::target::RegFile::vortex()
        },
        ..TargetDesc::vortex()
    };
    let run_with = |target: &TargetDesc, codegen_opt: bool| -> (Vec<u32>, Vec<u32>, usize) {
        let (mut m, infos) =
            compile_kernels(src, &FrontendOptions::default()).unwrap();
        let mut cfg = OptLevel::O3.config();
        cfg.verify = true;
        run_middle_end_with(&mut m, &cfg, target);
        let img = build_image(
            &m,
            &format!("__main_{}", infos[0].name),
            &BackendOptions {
                codegen_opt,
                target: *target,
                ..Default::default()
            },
        )
        .unwrap();
        // The test is only meaningful if both read-modify-write paths
        // (select -> vx_cmov, cmpxchg -> amocas) made it into the image.
        use volt::backend::isa::Op;
        assert!(
            img.code.iter().any(|i| i.op == Op::CMOV),
            "stress kernel lost its vx_cmov"
        );
        assert!(
            img.code.iter().any(|i| i.op == Op::AMOCAS),
            "stress kernel lost its amocas"
        );
        let mut dev = VoltDevice::new(img.clone(), SimConfig::from_target(target));
        let n = 128u32;
        let out = dev.malloc(n * 4);
        let lock = dev.malloc(4 * 4);
        dev.write_u32s(out, &vec![0u32; n as usize]).unwrap();
        dev.write_u32s(lock, &[0u32; 4]).unwrap();
        dev.launch(
            "stress",
            [2, 1, 1],
            [64, 1, 1],
            &[
                volt::runtime::ArgValue::Ptr(out),
                volt::runtime::ArgValue::Ptr(lock),
                volt::runtime::ArgValue::I32(n as i32),
            ],
        )
        .unwrap();
        (
            dev.read_u32s(out, n as usize).unwrap(),
            dev.read_u32s(lock, 4).unwrap(),
            img.spill_insts(),
        )
    };
    let (out_on, lock_on, spills_on) = run_with(&narrow, true);
    let (out_off, lock_off, spills_off) = run_with(&narrow, false);
    let (out_wide, lock_wide, _) = run_with(&TargetDesc::vortex(), true);
    assert!(spills_on > 0, "narrow regfile must actually spill");
    assert!(spills_off > 0);
    assert_eq!(out_on, out_off, "rung on/off results differ under spills");
    assert_eq!(lock_on, lock_off, "CAS results differ under spills");
    assert_eq!(out_on, out_wide, "narrow-regfile results differ from wide");
    assert_eq!(lock_on, lock_wide);
    // Host-side expected values for the non-atomic output.
    for i in 0..128u32 {
        let (a, b, c, d) = (i * 3 + 1, i * 5 + 2, i * 7 + 3, i * 11 + 4);
        let e = a.wrapping_mul(b).wrapping_add(c.wrapping_mul(d));
        let f = a + b + c + d;
        let g = e ^ f;
        let h = (a & c) + (b | d);
        let v = if i % 2 == 0 { e.wrapping_add(h) } else { f.wrapping_add(g) };
        let want = v.wrapping_add(a).wrapping_add(e).wrapping_sub(g);
        assert_eq!(out_on[i as usize], want, "i={i}");
    }
    // Every lock slot was CAS'd exactly once from 0: the winner is some
    // thread id+1 congruent to the slot (mod 4).
    for (j, &l) in lock_on.iter().enumerate() {
        assert!(l != 0, "slot {j} never won a CAS");
        assert_eq!((l - 1) as usize % 4, j, "slot {j} holds {l}");
    }
}
