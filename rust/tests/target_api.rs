//! Cross-target semantics: the same source compiled through one
//! middle-end for `vortex` and `vortex-min` must produce bit-identical
//! results, with select→branch legalization proven on `vortex-min`
//! (no `vx_cmov` in its images), typed errors for hardware warp
//! primitives the target lacks, a target-keyed binary cache, and loud
//! simulator traps on image/target mismatches.

use std::sync::Arc;
use volt::backend::isa::Op;
use volt::driver::{fingerprint, Program, Session, VoltError, VoltOptions};
use volt::runtime::{ArgValue, VoltDevice};
use volt::sim::SimConfig;
use volt::target::TargetDesc;
use volt::transform::OptLevel;

/// The pass.rs ladder kernel as VCL source: a divergent loop (per-lane
/// trip counts) followed by a divergent if/else — the shape that forms a
/// select on ZiCond targets and a branch diamond on vortex-min.
const LADDER_SRC: &str = r#"
kernel void k(global int* out, int n) {
    int i = get_global_id(0);
    int s = 0;
    for (int j = 0; j < i % 7; j++) { s += j; }
    int v = 0;
    if ((i & 1) != 0) { v = s * 3; } else { v = s + 100; }
    if (i < n) out[i] = v;
}
"#;

fn compile_on(target: &str, opt: OptLevel, src: &str) -> (Session, Arc<Program>) {
    let opts = VoltOptions::builder()
        .target(target)
        .opt_level(opt)
        .build()
        .unwrap();
    let s = Session::new(opts);
    let p = s.compile(src).unwrap();
    (s, p)
}

fn run_k_on(target: &str, opt: OptLevel, src: &str, n: u32) -> Vec<u32> {
    let (s, p) = compile_on(target, opt, src);
    let mut st = s.create_stream(&p);
    let buf = st.malloc(n * 4);
    st.enqueue_write_u32(buf, &vec![0u32; n as usize]).unwrap();
    st.enqueue_launch(
        "k",
        [2, 1, 1],
        [64, 1, 1],
        &[ArgValue::Ptr(buf), ArgValue::I32(n as i32)],
    )
    .unwrap();
    let t = st.enqueue_read_u32(buf, n as usize);
    st.synchronize().unwrap();
    st.take_u32(t).unwrap()
}

/// The ladder kernel produces bit-identical outputs on both built-in
/// targets, at the ladder's top rung, and matches the host-side model.
#[test]
fn ladder_kernel_bit_identical_across_targets() {
    let n = 128u32;
    let vortex = run_k_on("vortex", OptLevel::O3, LADDER_SRC, n);
    let min = run_k_on("vortex-min", OptLevel::O3, LADDER_SRC, n);
    assert_eq!(vortex, min, "cross-target outputs diverged");
    let host: Vec<u32> = (0..n)
        .map(|i| {
            let s: u32 = (0..i % 7).sum();
            if i & 1 != 0 {
                s * 3
            } else {
                s + 100
            }
        })
        .collect();
    assert_eq!(vortex, host, "device disagrees with the host model");
    // Recon too (the paper's default rung).
    assert_eq!(
        run_k_on("vortex", OptLevel::Recon, LADDER_SRC, n),
        run_k_on("vortex-min", OptLevel::Recon, LADDER_SRC, n)
    );
}

/// Select→branch legalization is structural: the vortex image keeps the
/// select as vx_cmov, the vortex-min image contains no gated op at all.
#[test]
fn vortex_min_images_are_free_of_gated_ops() {
    let (_s, pv) = compile_on("vortex", OptLevel::O3, LADDER_SRC);
    assert!(
        pv.image.code.iter().any(|i| i.op == Op::CMOV),
        "vortex @ O3 should form a select for the if/else diamond"
    );
    let (_s, pm) = compile_on("vortex-min", OptLevel::O3, LADDER_SRC);
    let min = TargetDesc::vortex_min();
    for inst in &pm.image.code {
        assert!(
            min.supports_op(inst.op),
            "gated op {:?} in a vortex-min image",
            inst.op
        );
    }
    assert_eq!(pm.image.target, "vortex-min");
    assert_eq!(pv.image.target, "vortex");
}

const SHFL_SRC: &str = r#"
__global__ void k(int* out) {
    int l = lane_id();
    out[l] = __shfl(l, 0);
}
"#;

const VOTE_SRC: &str = r#"
__global__ void k(int* out) {
    int l = lane_id();
    out[l] = __any(l > 0);
}
"#;

/// A shfl/vote kernel on vortex-min with hardware lowering requested is
/// a typed back-end error naming the missing extension — never a
/// miscompile. The software-emulation path compiles and runs.
#[test]
fn hw_warp_builtins_on_vortex_min_are_typed_errors() {
    use volt::frontend::Dialect;
    for (src, gate) in [(SHFL_SRC, "shfl"), (VOTE_SRC, "vote")] {
        let opts = VoltOptions::builder()
            .target("vortex-min")
            .dialect(Dialect::Cuda)
            .warp_hw(true)
            .build()
            .unwrap();
        let s = Session::new(opts);
        let e = s.compile(src).unwrap_err();
        match &e {
            VoltError::Backend(be) => {
                assert!(be.msg.contains(gate), "{gate}: {be}");
                assert!(be.msg.contains("vortex-min"), "{be}");
            }
            other => panic!("expected Backend error for {gate}, got {other:?}"),
        }
        // Software emulation: same kernel compiles and runs to the same
        // answers a vortex device produces.
        let opts = VoltOptions::builder()
            .target("vortex-min")
            .dialect(Dialect::Cuda)
            .warp_hw(false)
            .build()
            .unwrap();
        let s = Session::new(opts);
        let p = s.compile(src).unwrap();
        let mut st = s.create_stream(&p);
        let buf = st.malloc(32 * 4);
        st.enqueue_launch("k", [1, 1, 1], [32, 1, 1], &[ArgValue::Ptr(buf)])
            .unwrap();
        let t = st.enqueue_read_u32(buf, 32);
        st.synchronize().unwrap();
        let got = st.take_u32(t).unwrap();
        let want: Vec<u32> = match gate {
            "shfl" => vec![0; 32],
            _ => (0..32).map(|_| 1u32).collect(),
        };
        assert_eq!(got, want, "{gate} sw emulation on vortex-min");
    }
}

/// Same source, two targets → two cache keys; same source, same target →
/// one. The Session serves the hit from the cache (pointer-equal Arc).
#[test]
fn binary_cache_is_keyed_by_target() {
    let vortex = VoltOptions::builder().target("vortex").build().unwrap();
    let min = VoltOptions::builder().target("vortex-min").build().unwrap();
    assert_ne!(
        fingerprint(LADDER_SRC, &vortex),
        fingerprint(LADDER_SRC, &min),
        "two targets must occupy two cache entries"
    );
    assert_eq!(fingerprint(LADDER_SRC, &vortex), fingerprint(LADDER_SRC, &vortex));
    let s = Session::new(vortex);
    let p1 = s.compile(LADDER_SRC).unwrap();
    let p2 = s.compile(LADDER_SRC).unwrap();
    assert!(Arc::ptr_eq(&p1, &p2), "same target: cache hit");
    assert_eq!(s.cache_stats().hits, 1);
    let sm = Session::new(min);
    let pm = sm.compile(LADDER_SRC).unwrap();
    assert_ne!(p1.fingerprint, pm.fingerprint);
    assert_ne!(
        p1.image.code.len(),
        0,
        "sanity: programs materialized"
    );
}

/// Running a vortex image (with vx_cmov) on a vortex-min device is a
/// loud simulator trap naming the missing extension, not silent wrong
/// answers.
#[test]
fn device_traps_on_undeclared_extension_ops() {
    let (_s, pv) = compile_on("vortex", OptLevel::O3, LADDER_SRC);
    assert!(pv.image.code.iter().any(|i| i.op == Op::CMOV));
    let min_cfg = SimConfig::from_target(&TargetDesc::vortex_min());
    let mut dev = VoltDevice::new(pv.image.clone(), min_cfg);
    let buf = dev.malloc(128 * 4);
    let err = dev
        .launch(
            "k",
            [2, 1, 1],
            [64, 1, 1],
            &[ArgValue::Ptr(buf), ArgValue::I32(128)],
        )
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("zicond"), "trap should name the gate: {msg}");
    assert!(msg.contains("illegal instruction"), "{msg}");
}

/// Stream profiling and chrome traces are stamped with the target.
#[test]
fn profiles_and_traces_carry_the_target() {
    let opts = VoltOptions::builder()
        .target("vortex-min")
        .profiling(true)
        .build()
        .unwrap();
    let s = Session::new(opts);
    let p = s.compile(LADDER_SRC).unwrap();
    let mut st = s.create_stream(&p);
    let buf = st.malloc(128 * 4);
    st.enqueue_launch(
        "k",
        [2, 1, 1],
        [64, 1, 1],
        &[ArgValue::Ptr(buf), ArgValue::I32(128)],
    )
    .unwrap();
    st.synchronize().unwrap();
    let profiles = st.profiles();
    assert_eq!(profiles.len(), 1);
    assert_eq!(profiles[0].target, "vortex-min");
    let trace = st.chrome_trace();
    volt::prof::validate_json(&trace).unwrap();
    assert!(trace.contains("\"target\":\"vortex-min\""), "{trace}");
}

/// Capability caps at option-build time: typed errors, not clamping.
#[test]
fn geometry_above_caps_is_invalid_options() {
    let e = VoltOptions::builder()
        .target("vortex-min")
        .sim(SimConfig {
            num_cores: 4,
            ..SimConfig::from_target(&TargetDesc::vortex_min())
        })
        .build()
        .unwrap_err();
    assert!(matches!(e, VoltError::InvalidOptions { .. }), "{e}");
    assert!(e.to_string().contains("num_cores"), "{e}");
    // Launch geometry still validates against the (capped) device.
    let opts = VoltOptions::builder().target("vortex-min").build().unwrap();
    let s = Session::new(opts);
    let p = s.compile(LADDER_SRC).unwrap();
    let mut st = s.create_stream(&p);
    let buf = st.malloc(4);
    st.enqueue_launch(
        "k",
        [1, 1, 1],
        [512, 1, 1], // 16 warps of 32 > vortex-min's 8 warps/core
        &[ArgValue::Ptr(buf), ArgValue::I32(1)],
    )
    .unwrap();
    let e = st.synchronize().unwrap_err();
    assert!(matches!(e, VoltError::Runtime(_)), "{e}");
}
