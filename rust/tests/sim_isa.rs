//! Direct ISA-level simulator tests: hand-assembled programs exercising
//! the Vortex extension semantics (Table 2) — split/join nesting, pred
//! loops with mask restore, tmc retirement, barrier synchronisation,
//! warp shuffles/votes and the ZiCond conditional move.

use std::collections::HashMap;
use volt::backend::emit::{ProgramImage, DATA_BASE, HEAP_BASE};
use volt::backend::isa::{MachInst, Op};
use volt::sim::{Gpu, SimConfig, SimStats};
use volt::target::{AddressMap, TargetDesc};

fn mk(op: Op, rd: u8, rs1: u8, rs2: u8, imm: i32) -> MachInst {
    MachInst {
        op,
        rd,
        rs1,
        rs2,
        imm,
    }
}

fn image(code: Vec<MachInst>) -> ProgramImage {
    let words = code.iter().map(|i| i.encode()).collect();
    let pc_loc = vec![None; code.len()];
    let pc_spill = vec![false; code.len()];
    ProgramImage {
        code,
        words,
        data: vec![],
        data_end: DATA_BASE + 4096,
        global_addr: HashMap::new(),
        global_size: HashMap::new(),
        args_addr: DATA_BASE,
        local_mem_size: 0,
        kernel: "raw".into(),
        func_entries: HashMap::new(),
        pc_loc,
        crt0_len: 0,
        pc_spill,
        target: "vortex".into(),
        addr_map: AddressMap::vortex(),
    }
}

fn run(code: Vec<MachInst>, cfg: SimConfig) -> (Gpu, SimStats) {
    let img = image(code);
    let mut gpu = Gpu::load(&img, cfg);
    let stats = gpu.run().expect("sim run");
    (gpu, stats)
}

fn one_core() -> SimConfig {
    SimConfig {
        num_cores: 1,
        warps_per_core: 2,
        threads_per_warp: 8,
        ..SimConfig::default()
    }
}

const OUT: i32 = HEAP_BASE as i32;

/// Activate all lanes, store lane ids to memory, retire.
#[test]
fn tmc_and_lane_stores() {
    let code = vec![
        mk(Op::LI, 5, 0, 0, -1),
        mk(Op::TMC, 0, 5, 0, 0),
        mk(Op::CSRR, 6, 0, 0, 0),  // lane id
        mk(Op::LI, 7, 0, 0, OUT),
        mk(Op::SLLI, 8, 6, 0, 2),
        mk(Op::ADD, 7, 7, 8, 0),
        mk(Op::SW, 0, 7, 6, 0), // mem[out + 4*lane] = lane
        mk(Op::TMC, 0, 0, 0, 0), // retire
    ];
    let (gpu, stats) = run(code, one_core());
    for l in 0..8u32 {
        assert_eq!(gpu.mem.read_u32(OUT as u32 + l * 4).unwrap(), l);
    }
    assert_eq!(stats.tmcs, 2);
}

/// Divergent split: even lanes add 100, odd lanes add 200; all reconverge
/// and store.
#[test]
fn split_join_divergence() {
    // x6 = lane; x7 = lane & 1; split(x7 == 0 -> then)
    let code = vec![
        /*0*/ mk(Op::LI, 5, 0, 0, -1),
        /*1*/ mk(Op::TMC, 0, 5, 0, 0),
        /*2*/ mk(Op::CSRR, 6, 0, 0, 0),
        /*3*/ mk(Op::ANDI, 7, 6, 0, 1),
        /*4*/ mk(Op::SEQ, 8, 7, 0, 0), // pred: even lane
        /*5*/ mk(Op::SPLIT, 0, 8, 0, MachInst::pack_split(8, 10)), // else=8 join=10
        /*6 then*/ mk(Op::ADDI, 9, 6, 0, 100),
        /*7*/ mk(Op::J, 0, 0, 0, 10),
        /*8 else*/ mk(Op::ADDI, 9, 6, 0, 200),
        /*9*/ mk(Op::J, 0, 0, 0, 10),
        /*10 join*/ mk(Op::JOIN, 0, 0, 0, 0),
        /*11*/ mk(Op::LI, 10, 0, 0, OUT),
        /*12*/ mk(Op::SLLI, 11, 6, 0, 2),
        /*13*/ mk(Op::ADD, 10, 10, 11, 0),
        /*14*/ mk(Op::SW, 0, 10, 9, 0),
        /*15*/ mk(Op::TMC, 0, 0, 0, 0),
    ];
    let (gpu, stats) = run(code, one_core());
    for l in 0..8u32 {
        let want = if l % 2 == 0 { l + 100 } else { l + 200 };
        assert_eq!(gpu.mem.read_u32(OUT as u32 + l * 4).unwrap(), want, "lane {l}");
    }
    assert_eq!(stats.splits, 1); // single live warp
    assert!(stats.joins >= stats.splits);
}

/// vx_pred loop: each lane loops lane+1 times; mask restored at exit.
#[test]
fn pred_loop_mask_restore() {
    let code = vec![
        /*0*/ mk(Op::LI, 5, 0, 0, -1),
        /*1*/ mk(Op::TMC, 0, 5, 0, 0),
        /*2*/ mk(Op::CSRR, 6, 0, 0, 0),  // lane
        /*3*/ mk(Op::ADDI, 7, 6, 0, 1),  // trips = lane+1
        /*4*/ mk(Op::LI, 8, 0, 0, 0),    // counter
        /*5*/ mk(Op::MASK, 9, 0, 0, 0),  // save entry mask
        /*6 header*/ mk(Op::ADDI, 8, 8, 0, 1),
        /*7*/ mk(Op::SLT, 10, 8, 7, 0), // continue pred: counter < trips
        /*8*/ mk(Op::PRED, 0, 10, 9, 10), // exit -> 10
        /*9*/ mk(Op::J, 0, 0, 0, 6),
        /*10 exit*/ mk(Op::MASK, 11, 0, 0, 0),
        /*11*/ mk(Op::LI, 12, 0, 0, OUT),
        /*12*/ mk(Op::SLLI, 13, 6, 0, 2),
        /*13*/ mk(Op::ADD, 12, 12, 13, 0),
        /*14*/ mk(Op::SW, 0, 12, 8, 0),  // store per-lane trip count
        /*15*/ mk(Op::LI, 14, 0, 0, OUT + 64),
        /*16*/ mk(Op::ADD, 14, 14, 13, 0),
        /*17*/ mk(Op::SW, 0, 14, 11, 0), // store post-loop mask
        /*18*/ mk(Op::TMC, 0, 0, 0, 0),
    ];
    let (gpu, stats) = run(code, one_core());
    for l in 0..8u32 {
        assert_eq!(
            gpu.mem.read_u32(OUT as u32 + l * 4).unwrap(),
            l + 1,
            "lane {l} trip count"
        );
        // Mask fully restored after the loop.
        assert_eq!(
            gpu.mem.read_u32(OUT as u32 + 64 + l * 4).unwrap(),
            0xff,
            "lane {l} restored mask"
        );
    }
    assert!(stats.preds > 0);
}

/// Warp ops: ballot/vote/shfl semantics.
#[test]
fn warp_primitives() {
    let code = vec![
        mk(Op::LI, 5, 0, 0, -1),
        mk(Op::TMC, 0, 5, 0, 0),
        mk(Op::CSRR, 6, 0, 0, 0),
        mk(Op::ANDI, 7, 6, 0, 1),   // odd-lane pred
        mk(Op::BALLOT, 8, 7, 0, 0), // 0xAA
        mk(Op::VOTEANY, 9, 7, 0, 0),
        mk(Op::VOTEALL, 10, 7, 0, 0),
        // shfl: read lane+1 (mod nt) of lane id -> rotated ids
        mk(Op::ADDI, 11, 6, 0, 1),
        mk(Op::SHFL, 12, 6, 11, 0),
        mk(Op::LI, 13, 0, 0, OUT),
        mk(Op::SLLI, 14, 6, 0, 2),
        mk(Op::ADD, 13, 13, 14, 0),
        mk(Op::SW, 0, 13, 8, 0),
        mk(Op::SW, 0, 13, 9, 64),
        mk(Op::SW, 0, 13, 10, 128),
        mk(Op::SW, 0, 13, 12, 192),
        mk(Op::TMC, 0, 0, 0, 0),
    ];
    let (gpu, _) = run(code, one_core());
    for l in 0..8u32 {
        let base = OUT as u32 + l * 4;
        assert_eq!(gpu.mem.read_u32(base).unwrap(), 0xAA, "ballot");
        assert_eq!(gpu.mem.read_u32(base + 64).unwrap(), 1, "any");
        assert_eq!(gpu.mem.read_u32(base + 128).unwrap(), 0, "all");
        assert_eq!(gpu.mem.read_u32(base + 192).unwrap(), (l + 1) % 8, "shfl");
    }
}

/// CMOV: per-lane conditional move (the ZiCond vx_cmov).
#[test]
fn cmov_semantics() {
    let code = vec![
        mk(Op::LI, 5, 0, 0, -1),
        mk(Op::TMC, 0, 5, 0, 0),
        mk(Op::CSRR, 6, 0, 0, 0),
        mk(Op::ANDI, 7, 6, 0, 1),  // cond = odd
        mk(Op::LI, 8, 0, 0, 111),  // default
        mk(Op::LI, 9, 0, 0, 222),
        mk(Op::CMOV, 8, 7, 9, 0),  // odd lanes: 222
        mk(Op::LI, 10, 0, 0, OUT),
        mk(Op::SLLI, 11, 6, 0, 2),
        mk(Op::ADD, 10, 10, 11, 0),
        mk(Op::SW, 0, 10, 8, 0),
        mk(Op::TMC, 0, 0, 0, 0),
    ];
    let (gpu, _) = run(code, one_core());
    for l in 0..8u32 {
        let want = if l % 2 == 1 { 222 } else { 111 };
        assert_eq!(gpu.mem.read_u32(OUT as u32 + l * 4).unwrap(), want);
    }
}

/// wspawn + barrier: two warps rendezvous, then warp 1 writes after warp 0.
#[test]
fn wspawn_and_barrier() {
    let code = vec![
        /*0*/ mk(Op::LI, 5, 0, 0, 1),
        /*1*/ mk(Op::WSPAWN, 0, 5, 0, 2), // spawn warp1 at 2
        /*2*/ mk(Op::LI, 6, 0, 0, -1),
        /*3*/ mk(Op::TMC, 0, 6, 0, 0),
        /*4*/ mk(Op::CSRR, 7, 0, 0, 1), // warp id
        /*5*/ mk(Op::LI, 8, 0, 0, 2),
        /*6*/ mk(Op::BAR, 0, 8, 0, 0), // both warps arrive
        /*7*/ mk(Op::LI, 9, 0, 0, OUT),
        /*8*/ mk(Op::SLLI, 10, 7, 0, 2),
        /*9*/ mk(Op::ADD, 9, 9, 10, 0),
        /*10*/ mk(Op::ADDI, 11, 7, 0, 40),
        /*11*/ mk(Op::SW, 0, 9, 11, 0),
        /*12*/ mk(Op::TMC, 0, 0, 0, 0),
    ];
    let (gpu, stats) = run(code, one_core());
    assert_eq!(gpu.mem.read_u32(OUT as u32).unwrap(), 40);
    assert_eq!(gpu.mem.read_u32(OUT as u32 + 4).unwrap(), 41);
    assert!(stats.barriers_executed >= 2);
}

/// Unmanaged divergent branch traps (the compiler-contract check).
#[test]
fn divergent_branch_traps() {
    let code = vec![
        mk(Op::LI, 5, 0, 0, -1),
        mk(Op::TMC, 0, 5, 0, 0),
        mk(Op::CSRR, 6, 0, 0, 0),
        mk(Op::ANDI, 7, 6, 0, 1),
        mk(Op::BNEZ, 0, 7, 0, 6), // divergent cond, no split!
        mk(Op::TMC, 0, 0, 0, 0),
        mk(Op::TMC, 0, 0, 0, 0),
    ];
    let img = image(code);
    let mut gpu = Gpu::load(&img, one_core());
    let err = gpu.run().unwrap_err();
    assert!(err.msg.contains("non-uniform"), "{err}");
}

/// An unknown CSR index is a trap, not a silent NumCores read.
#[test]
fn unknown_csr_traps() {
    let code = vec![
        mk(Op::LI, 5, 0, 0, -1),
        mk(Op::TMC, 0, 5, 0, 0),
        mk(Op::CSRR, 6, 0, 0, 99), // no such CSR
        mk(Op::TMC, 0, 0, 0, 0),
    ];
    let img = image(code);
    let mut gpu = Gpu::load(&img, one_core());
    let err = gpu.run().unwrap_err();
    assert!(err.msg.contains("unknown CSR"), "{err}");
    assert!(err.msg.contains("99"), "{err}");
}

/// Feature-gated opcodes outside the device's declared feature set trap
/// with a message naming the gate — the image/target-mismatch guard.
#[test]
fn undeclared_extension_ops_trap() {
    let min_features = TargetDesc::vortex_min().features;
    for (op, gate) in [
        (Op::CMOV, "zicond"),
        (Op::SHFL, "shfl"),
        (Op::BALLOT, "vote"),
        (Op::VOTEALL, "vote"),
        (Op::VOTEANY, "vote"),
    ] {
        let code = vec![
            mk(Op::LI, 5, 0, 0, -1),
            mk(Op::TMC, 0, 5, 0, 0),
            mk(op, 6, 5, 5, 0),
            mk(Op::TMC, 0, 0, 0, 0),
        ];
        let img = image(code.clone());
        let cfg = SimConfig {
            features: min_features,
            ..one_core()
        };
        let mut gpu = Gpu::load(&img, cfg);
        let err = gpu.run().unwrap_err();
        assert!(err.msg.contains("illegal instruction"), "{op:?}: {err}");
        assert!(err.msg.contains(gate), "{op:?}: {err}");
        // The same program runs on a full-featured device.
        let mut gpu = Gpu::load(&image(code), one_core());
        gpu.run().unwrap_or_else(|e| panic!("{op:?} on vortex: {e}"));
    }
}

/// Atomics serialize per lane in lane order.
#[test]
fn atomic_add_all_lanes() {
    let code = vec![
        mk(Op::LI, 5, 0, 0, -1),
        mk(Op::TMC, 0, 5, 0, 0),
        mk(Op::LI, 6, 0, 0, OUT),
        mk(Op::LI, 7, 0, 0, 1),
        mk(Op::AMOADD, 8, 6, 7, 0),
        mk(Op::CSRR, 9, 0, 0, 0),
        mk(Op::SLLI, 10, 9, 0, 2),
        mk(Op::ADD, 11, 6, 10, 0),
        mk(Op::SW, 0, 11, 8, 64), // store each lane's observed old value
        mk(Op::TMC, 0, 0, 0, 0),
    ];
    let cfg = SimConfig {
        num_cores: 1,
        warps_per_core: 1,
        threads_per_warp: 8,
        ..SimConfig::default()
    };
    let (gpu, stats) = run(code, cfg);
    assert_eq!(gpu.mem.read_u32(OUT as u32).unwrap(), 8);
    // Old values are 0..7 in lane order.
    for l in 0..8u32 {
        assert_eq!(gpu.mem.read_u32(OUT as u32 + 64 + l * 4).unwrap(), l);
    }
    assert_eq!(stats.atomics, 1);
}
